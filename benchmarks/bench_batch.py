"""Batched vs sequential sparsification throughput (the batching win).

8 small graphs, one padded `GraphBatch` dispatch vs 8 sequential
`lgrass_sparsify` calls, both on the basic (scan) schedule — the right
engine for one CPU core, as in table3/fig5 (the lockstep schedule's lane
parallelism only pays on wide hardware). Two numbers:

  * steady state — both paths pre-compiled; the batch wins because one
    vmapped program replaces 8 loop dispatches over tiny operands.
  * cold start, mixed sizes — 8 distinct (n, L) shapes served through
    `SparsifyService`: sequential jit compiles one program per shape,
    the service buckets every graph into one padded shape and compiles
    once. This is the number that matters for serving traffic.

    PYTHONPATH=src python benchmarks/bench_batch.py [--smoke]
"""
import argparse
import time

import numpy as np

from repro.core import lgrass_sparsify, lgrass_sparsify_batch
from repro.core.graph import GraphBatch, random_connected_graph
from repro.serve.sparsify_service import SparsifyService

BATCH = 8
K_CAP = 32
BUDGET = 8


def _graphs_same_shape(n=64, extra=128):
    return [random_connected_graph(n, extra, seed=100 + i, weight="lognormal")
            for i in range(BATCH)]


def _graphs_mixed():
    # 8 distinct (n, L) shapes inside one power-of-two bucket
    return [random_connected_graph(40 + 3 * i, 80 + 5 * i, seed=200 + i)
            for i in range(BATCH)]


def _time(fn, reps):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(quick: bool = False):
    reps = 2 if quick else 5
    graphs = _graphs_same_shape()
    batch = GraphBatch.from_graphs(graphs)

    def sequential():
        return [lgrass_sparsify(g, budget=BUDGET, k_cap=K_CAP,
                                parallel=False) for g in graphs]

    def batched():
        return lgrass_sparsify_batch(batch, budget=BUDGET, k_cap=K_CAP,
                                     parallel=False)

    # warm both paths (compile), and check equivalence while at it
    for a, b in zip(sequential(), batched()):
        assert np.array_equal(a.edge_mask, b.edge_mask)

    t_seq = _time(sequential, reps)
    t_bat = _time(batched, reps)

    rows = [
        (f"batch.steady.sequential_x{BATCH}", t_seq * 1e6, ""),
        (f"batch.steady.batched_x{BATCH}", t_bat * 1e6, ""),
        ("batch.steady.speedup", 0.0, round(t_seq / t_bat, 2)),
    ]

    if not quick:
        mixed = _graphs_mixed()
        t0 = time.perf_counter()
        r_seq = [lgrass_sparsify(g, budget=BUDGET, k_cap=K_CAP,
                                 parallel=False) for g in mixed]
        t_cold_seq = time.perf_counter() - t0  # 8 shapes -> 8 compiles

        svc = SparsifyService(k_cap=K_CAP, parallel=False)
        t0 = time.perf_counter()
        r_svc = svc.sparsify(mixed, budget=BUDGET)
        t_cold_svc = time.perf_counter() - t0  # 1 bucket -> 1 compile
        for a, b in zip(r_seq, r_svc):
            assert np.array_equal(a.edge_mask, b.edge_mask)
        rows += [
            (f"batch.cold_mixed.sequential_x{BATCH}", t_cold_seq * 1e6, ""),
            (f"batch.cold_mixed.service_x{BATCH}", t_cold_svc * 1e6,
             f"{svc.stats.n_dispatches} dispatch(es)"),
            ("batch.cold_mixed.speedup", 0.0,
             round(t_cold_seq / t_cold_svc, 2)),
        ]
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / few reps (CI smoke job)")
    args = ap.parse_args()
    rows = run(quick=args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    steady = rows[2][2]
    print(f"steady state: batched is {steady}x sequential "
          f"({'WIN' if steady > 1 else 'LOSS'})")
