# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes (CI)")
    ap.add_argument("--only", default=None,
                    help="comma list: table3,table2,fig5,kernels,roofline,"
                         "batch,recovery")
    args = ap.parse_args()

    from benchmarks import (bench_batch, bench_kernels, bench_recovery,
                            fig5_linearity, roofline, table2_breakdown,
                            table3_execution_time)

    suites = {
        "table3": table3_execution_time.run,
        "table2": table2_breakdown.run,
        "fig5": fig5_linearity.run,
        "kernels": bench_kernels.run,
        "roofline": roofline.run,
        "batch": bench_batch.run,
        "recovery": bench_recovery.run,
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    print("name,us_per_call,derived")
    for name in chosen:
        try:
            rows = suites[name](quick=args.quick)
        except Exception as e:  # report but keep the suite going
            print(f"{name}.ERROR,0,{e!r}", file=sys.stdout)
            continue
        for row in rows:
            n, us, derived = row
            print(f"{n},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
