# One function per paper table. Print ``name,us_per_call,derived`` CSV;
# ``--json`` additionally writes a BENCH_*.json document that embeds the
# pipeline configuration (backend, phase-1 schedule, chunk sizes), so
# benchmark trajectories across PRs compare like with like — a number
# measured under schedule="scan" must never be read against one measured
# under schedule="chunked" without the config saying so.
import argparse
import json
import sys


def _bench_config(quick: bool):
    """The knobs that determine what the numbers mean.

    `pipeline_defaults` describes what a row gets when its suite does
    NOT pin an engine — the configuration every default-path row (e.g.
    the e2e recovery rows) ran under. Rows that deliberately pin a
    different engine (bench_phase1's scan_basic/scan_parallel/lifting
    rows, fig5's scan schedule, table2's k_cap=8 probe) say so in their
    name or `derived` field; those annotations, not this block, are
    authoritative for such rows.
    """
    import jax

    from repro.core.pow2 import auto_chunk

    return {
        "backend": jax.default_backend(),
        "quick": bool(quick),
        "jax": jax.__version__,
        "pipeline_defaults": {
            "phase1_schedule": "chunked",
            "phase1_chunk_policy": "auto_pow2_sqrt",
            "phase1_chunk_at_4k_edges": auto_chunk(4096),
            "use_euler_lca": True,
            "recovery_chunk": 32,
            "k_cap": 32,
            "bfs_engine": "doubling",
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes (CI)")
    ap.add_argument("--only", default=None,
                    help="comma list: table3,table2,fig5,kernels,roofline,"
                         "batch,recovery,phase1,bfs,service,spectral")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + config as JSON "
                         "(e.g. BENCH_pr4.json)")
    args = ap.parse_args()

    from benchmarks import (bench_batch, bench_bfs, bench_kernels,
                            bench_phase1, bench_recovery, bench_service,
                            bench_spectral, fig5_linearity, roofline,
                            table2_breakdown, table3_execution_time)

    suites = {
        "table3": table3_execution_time.run,
        "table2": table2_breakdown.run,
        "fig5": fig5_linearity.run,
        "kernels": bench_kernels.run,
        "roofline": roofline.run,
        "batch": bench_batch.run,
        "recovery": bench_recovery.run,
        "phase1": bench_phase1.run,
        "bfs": bench_bfs.run,
        "service": bench_service.run,
        "spectral": bench_spectral.run,
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    all_rows = []
    print("name,us_per_call,derived")
    for name in chosen:
        try:
            rows = suites[name](quick=args.quick)
        except Exception as e:  # report but keep the suite going
            print(f"{name}.ERROR,0,{e!r}", file=sys.stdout)
            all_rows.append({"name": f"{name}.ERROR", "us_per_call": 0.0,
                             "derived": repr(e)})
            continue
        for row in rows:
            n, us, derived = row
            print(f"{n},{us:.1f},{derived}")
            all_rows.append({"name": n, "us_per_call": round(float(us), 1),
                             "derived": derived})
    if args.json:
        doc = {"config": _bench_config(args.quick), "rows": all_rows}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
