"""Host vs device recovery latency (the tail PR 2 moved on-device).

Workload: `feeder_like_graph` — the chain-heavy radial topology where
almost every off-tree edge is non-crossing, so phase 1 decides nothing
and Algorithm 6 does all the work. This is the recovery-dominated
serving regime the refactor targets.

Three comparisons:

  * isolated tail — one graph's phase-1 outputs prepared up front, then
    `recover_host` (numpy replay) vs the jitted `recover_device`
    chunked scan on identical inputs.
  * batched tail — phase-1 outputs for 8 mixed-size graphs already
    device-resident; the host path then pays what serving actually
    pays: the device→host sync of the full per-edge dict, per-graph
    numpy glue, and 8 sequential interpreted replays. The device path
    is ONE `recover_device_batched` dispatch (glue + order sort + scan
    all on device) returning only masks.
  * end-to-end batch — `lgrass_sparsify_batch` with recovery="host" vs
    the fused recovery="device" program, one dispatch for everything.

Context for reading the numbers: the device replay is built from
batched LCA gathers — the TPU-native shape. On the CPU CI backend,
XLA's scalarised gathers pace the device path, while the host path
rides numpy's cache-friendly kernels; the device wins here come from
removing the sync + per-graph python, and grow with batch size. On an
accelerator the gap widens further because the host path's sync cost
is a real transfer, not a memcpy.

Since the phase-1 chunking PR the e2e rows compare two paths that both
run the chunked+Euler marking schedule, and the fused device path
additionally backs its recovery cover tables with the same Euler
tables — that flip is what moved e2e past parity (~1.33x at smoke
sizes). The full-size rows were then BFS-bound (diameter ~n feeder
chains pinned the ratio at ~1.0-1.1x) until the hop-doubling engine
(benchmarks/bench_bfs.py) collapsed the two traversal passes; both
paths share that win, so the absolute e2e dropped ~2.7x while the
host-vs-device ratio moved to the ~1.2x the remaining shared stages
(MST, marking) allow — bench_bfs records the engine before/after.

    PYTHONPATH=src python benchmarks/bench_recovery.py [--smoke]
"""
import argparse
import functools
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import lgrass_sparsify_batch
from repro.core.graph import GraphBatch, feeder_like_graph
from repro.core.lca import LiftingTables
from repro.core.marking import phase1_edge_views
from repro.core.recovery import (_recover_scan, recover_device,
                                 recover_host)
from repro.core.sort import sort_f32_desc_stable
from repro.core.sparsify import (_recovery_tail, phase1_device,
                                 phase1_device_batched, phase1_views_np)

BATCH = 8


def _time(fn, reps):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _mixed_graphs(quick):
    base = 96 if quick else 256
    step = 16 if quick else 64
    return [
        feeder_like_graph(base + step * i, base + step * i,
                          span=16 + 4 * (i % 3), seed=500 + i)
        for i in range(BATCH)
    ]


@functools.partial(jax.jit, static_argnames=("b_cap",))
def _device_tail_batched(d, u, v, edge_valid, budgets, b_cap):
    """On-device glue + order sort + chunked replay, vmapped — what the
    fused program runs after phase 1, as a standalone timed unit.
    b_cap is the tight per-batch bound (a pow2 bucket only matters for
    compile sharing across batches, which a benchmark doesn't need)."""
    def one(dd, bu, bv, bev, bb):
        t = LiftingTables(up=dd["up"], depth=dd["depth_t"])
        tree, crossing = dd["tree_mask"], dd["crossing"]
        acc, grp, dirty0 = phase1_edge_views(
            dd["perm"], dd["gidx"], dd["accept_sorted"],
            dd["group_overflow"], crossing)
        offtree = (~tree) & bev
        order = sort_f32_desc_stable(jnp.where(offtree, dd["crit"],
                                               -jnp.inf))
        return _recover_scan(t, bu, bv, dd["beta"], offtree, crossing,
                             order, acc, grp, dirty0, bb, b_cap,
                             chunk=16)
    return jax.vmap(one)(d, u, v, edge_valid, budgets)


def run(quick: bool = False):
    reps = 2 if quick else 5
    rows = []

    # --- isolated tail: recover_host vs recover_device, same inputs ---
    g = feeder_like_graph(192 if quick else 512, 192 if quick else 512,
                          span=24, seed=42)
    budget = max(4, g.n // 20)
    b_cap = max(budget, 8)  # tight static bound (no bucket sharing needed)
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    d1 = {k: np.asarray(x) for k, x in
          phase1_device(u, v, jnp.asarray(g.w, jnp.float32), g.n).items()}
    tree, crossing, accept, group, dirty0, order = phase1_views_np(d1, g.m)
    n_off = int((~tree).sum())

    def host_tail():
        return recover_host(
            g.n, g.u.astype(np.int64), g.v.astype(np.int64), tree,
            d1["parent_t"], d1["depth_t"], d1["up"], d1["beta"], crossing,
            order[:n_off], accept, group, dirty0, budget)

    dev_args = (
        jnp.asarray(d1["up"]), jnp.asarray(d1["depth_t"]), u, v,
        jnp.asarray(d1["beta"]), jnp.asarray(tree), jnp.asarray(crossing),
        jnp.asarray(order.astype(np.int32)), jnp.asarray(accept),
        jnp.asarray(group.astype(np.int32)), jnp.asarray(dirty0),
        jnp.int32(budget),
    )

    def device_tail():
        out, _ = recover_device(*dev_args, b_cap=b_cap, chunk=16)
        return out.block_until_ready()

    ref = host_tail()
    assert np.array_equal(np.asarray(device_tail()), ref)  # and warm jit
    t_host = _time(host_tail, reps)
    t_dev = _time(device_tail, reps)
    rows += [
        ("recovery.tail.host_us", t_host * 1e6, f"L={g.m}"),
        ("recovery.tail.device_us", t_dev * 1e6, f"b_cap={b_cap}"),
        ("recovery.tail.speedup", 0.0, round(t_host / t_dev, 2)),
    ]

    # --- batched tail: sync + 8 host replays vs ONE device dispatch ---
    graphs = _mixed_graphs(quick)
    batch = GraphBatch.from_graphs(graphs)
    ub = jnp.asarray(batch.u, jnp.int32)
    vb = jnp.asarray(batch.v, jnp.int32)
    evb = jnp.asarray(batch.edge_valid, bool)
    budgets = [max(1, round(0.05 * gg.n)) for gg in graphs]
    bcap_b = max(max(budgets), 8)  # tight static bound
    d = phase1_device_batched(ub, vb, jnp.asarray(batch.w, jnp.float32),
                              evb, batch.n_max, 32, False, None)
    jax.block_until_ready(d)
    bv = jnp.asarray(np.asarray(budgets, np.int32))

    def batched_host_tail():
        dd = {k: np.asarray(val) for k, val in d.items()}  # the sync
        return [
            _recovery_tail(gg, {k: val[i] for k, val in dd.items()}, b)
            for i, (gg, b) in enumerate(zip(graphs, budgets))
        ]

    def batched_device_tail():
        out, cnt = _device_tail_batched(d, ub, vb, evb, bv, bcap_b)
        return np.asarray(out), np.asarray(cnt)

    ref_b = batched_host_tail()
    got, _ = batched_device_tail()  # warms the jit too
    for i, (gg, r) in enumerate(zip(graphs, ref_b)):
        assert np.array_equal(got[i][: gg.m], r.accepted_mask), i
    t_bh = _time(batched_host_tail, reps)
    t_bd = _time(batched_device_tail, reps)
    rows += [
        (f"recovery.batch{BATCH}_tail.host_us", t_bh * 1e6,
         "sync + 8 replays"),
        (f"recovery.batch{BATCH}_tail.device_us", t_bd * 1e6, "1 dispatch"),
        (f"recovery.batch{BATCH}_tail.speedup", 0.0, round(t_bh / t_bd, 2)),
    ]

    # --- end-to-end: host-tail path vs fused device path ---
    def e2e_host():
        return lgrass_sparsify_batch(batch, parallel=False,
                                     recovery="host")

    def e2e_device():
        return lgrass_sparsify_batch(batch, parallel=False,
                                     recovery="device")

    for a, b in zip(e2e_host(), e2e_device()):  # warm both + equivalence
        assert np.array_equal(a.edge_mask, b.edge_mask)
    t_h = _time(e2e_host, reps)
    t_d = _time(e2e_device, reps)
    rows += [
        (f"recovery.e2e_batch{BATCH}.host_tail_us", t_h * 1e6, ""),
        (f"recovery.e2e_batch{BATCH}.device_us", t_d * 1e6, "1 dispatch"),
        (f"recovery.e2e_batch{BATCH}.speedup", 0.0, round(t_h / t_d, 2)),
    ]
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / few reps (CI smoke job)")
    args = ap.parse_args()
    rows = run(quick=args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    tail = rows[5][2]
    e2e = rows[-1][2]
    print(f"batched tail: device is {tail}x the sync+host path; "
          f"end-to-end: {e2e}x "
          f"({'WIN' if min(tail, e2e) > 1 else 'MIXED'})")
