"""BFS engines: level-synchronous vs hop-doubling / Euler rooting.

The two traversal passes (graph BFS for effective weights, tree BFS for
the lifting tables) were the measured next bottleneck after phase-1
chunking: O(diameter) tiny while_loop rounds, ~58% of batched phase-1
on feeder-chain inputs whose diameter is O(n). This bench isolates both
passes on the feeder family at full size (n >= 4k) and then re-runs the
bench_recovery end-to-end comparison under each engine, so the
before/after of the default flip is recorded in one place.

  * graph pass — `bfs_levels` vs `bfs_doubling` (Bellman–Ford
    relaxations + pointer doubling, O(log n) rounds on chains);
  * tree pass — `bfs_levels` restricted to the spanning tree vs
    `root_tree` (Euler-tour rooting via list ranking — no BFS at all);
  * e2e — `lgrass_sparsify_batch` host-tail vs fused device path on the
    full-size feeder batch, once with bfs_engine="levels" (the old
    default) and once with "doubling" (the new one).

All engines are bit-identical (asserted here before timing, and in
tests/test_bfs_doubling.py); this file only measures.

    PYTHONPATH=src python benchmarks/bench_bfs.py [--smoke]
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import lgrass_sparsify_batch
from repro.core.bfs import (bfs_doubling, bfs_levels, effective_weights,
                            root_tree, select_root)
from repro.core.graph import GraphBatch, feeder_like_graph
from repro.core.mst import boruvka_mst
from repro.core.sort import sort_f32_desc_stable

BATCH = 8


def _time(fn, reps):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _mixed_graphs(quick):
    """The bench_recovery full-size feeder batch (same generator)."""
    base = 96 if quick else 256
    step = 16 if quick else 64
    return [
        feeder_like_graph(base + step * i, base + step * i,
                          span=16 + 4 * (i % 3), seed=500 + i)
        for i in range(BATCH)
    ]


def run(quick: bool = False):
    reps = 2 if quick else 5
    rows = []

    # --- isolated passes: feeder chain, n >= 4k (512 for smoke) -------
    n = 512 if quick else 4096
    g = feeder_like_graph(n, n, span=24, seed=42)
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    w = jnp.asarray(g.w, jnp.float32)
    root = select_root(u, v, g.n)

    # the pipeline's actual spanning tree for the tree-restricted pass
    depth_g, _ = bfs_levels(u, v, g.n, root)
    eff = effective_weights(u, v, w, depth_g, g.n)
    perm = sort_f32_desc_stable(eff)
    rank = jnp.zeros_like(perm).at[perm].set(
        jnp.arange(g.m, dtype=jnp.int32))
    tree_mask = boruvka_mst(u, v, rank, g.n)
    jax.block_until_ready(tree_mask)

    def graph_levels():
        return jax.block_until_ready(bfs_levels(u, v, g.n, root))

    def graph_doubling():
        return jax.block_until_ready(bfs_doubling(u, v, g.n, root))

    def tree_levels():
        return jax.block_until_ready(
            bfs_levels(u, v, g.n, root, tree_mask))

    def tree_euler():
        return jax.block_until_ready(root_tree(u, v, g.n, root, tree_mask))

    # warm + bit-identity before any timing
    dl, pl = graph_levels()
    dd, pd = graph_doubling()
    assert np.array_equal(np.asarray(dl), np.asarray(dd))
    assert np.array_equal(np.asarray(pl), np.asarray(pd))
    tl_d, tl_p = tree_levels()
    te_d, te_p = tree_euler()
    assert np.array_equal(np.asarray(tl_d), np.asarray(te_d))
    assert np.array_equal(np.asarray(tl_p), np.asarray(te_p))

    t_gl = _time(graph_levels, reps)
    t_gd = _time(graph_doubling, reps)
    t_tl = _time(tree_levels, reps)
    t_te = _time(tree_euler, reps)
    diam = int(np.asarray(dl)[np.asarray(dl) < np.iinfo(np.int32).max].max())
    rows += [
        (f"bfs.graph_n{n}.levels_us", t_gl * 1e6, f"depth={diam}"),
        (f"bfs.graph_n{n}.doubling_us", t_gd * 1e6, ""),
        (f"bfs.graph_n{n}.speedup", 0.0, round(t_gl / t_gd, 2)),
        (f"bfs.tree_n{n}.levels_us", t_tl * 1e6,
         f"tree_depth={int(np.asarray(tl_d).max())}"),
        (f"bfs.tree_n{n}.euler_us", t_te * 1e6, "root_tree"),
        (f"bfs.tree_n{n}.speedup", 0.0, round(t_tl / t_te, 2)),
        (f"bfs.stage_n{n}.speedup", 0.0,
         round((t_gl + t_tl) / (t_gd + t_te), 2)),
    ]

    # --- e2e before/after: the bench_recovery comparison per engine ---
    def e2e_rows(tag, batch, e2e_reps):
        out = []
        for engine in ("levels", "doubling"):
            def e2e_host():
                return lgrass_sparsify_batch(batch, parallel=False,
                                             recovery="host",
                                             bfs_engine=engine)

            def e2e_device():
                return lgrass_sparsify_batch(batch, parallel=False,
                                             recovery="device",
                                             bfs_engine=engine)

            for a, b in zip(e2e_host(), e2e_device()):  # warm + equiv.
                assert np.array_equal(a.edge_mask, b.edge_mask)
            t_h = _time(e2e_host, e2e_reps)
            t_d = _time(e2e_device, e2e_reps)
            out += [
                (f"bfs.{tag}.{engine}.host_tail_us", t_h * 1e6, ""),
                (f"bfs.{tag}.{engine}.device_us", t_d * 1e6,
                 "1 dispatch"),
                (f"bfs.{tag}.{engine}.speedup", 0.0, round(t_h / t_d, 2)),
            ]
        return out

    rows += e2e_rows("e2e_feeder", GraphBatch.from_graphs(
        _mixed_graphs(quick)), reps)
    if not quick:
        # the diameter-bound regime the engine targets: feeder chains
        # at n >= 2k, where the levels engine pays O(n) rounds
        # 4 reps: the box's rep-to-rep spread at these sizes is large
        # enough that min-of-2 can invert the comparison
        big = [feeder_like_graph(2048 + 128 * i, 2048 + 128 * i,
                                 span=16 + 4 * (i % 3), seed=700 + i)
               for i in range(4)]
        rows += e2e_rows("e2e_bigfeeder", GraphBatch.from_graphs(big), 4)
    return rows


def _derived(rows, name):
    return [r[2] for r in rows if r[0] == name][0]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / few reps (CI smoke job)")
    args = ap.parse_args()
    rows = run(quick=args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    n = 512 if args.smoke else 4096
    stage = _derived(rows, f"bfs.stage_n{n}.speedup")
    before = _derived(rows, "bfs.e2e_feeder.levels.speedup")
    after = _derived(rows, "bfs.e2e_feeder.doubling.speedup")
    print(f"isolated BFS stage: {stage}x; e2e feeder host-vs-device: "
          f"{before}x (levels) -> {after}x (doubling) "
          f"({'WIN' if stage > 1 and after >= before else 'MIXED'})")
