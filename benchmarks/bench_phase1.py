"""Phase-1 marking schedules in isolation (the stage PR "chunked" moved).

Workload: the synthetic 4K-node power-grid case (official case1 shape) —
the regime the acceptance bar names — plus a smaller mixed case under
--smoke for CI. The pipeline up to the sorted group layout (EFF → MST →
LCA → RES → SORT) runs once; each schedule then re-runs ONLY the MARK
stage as its own jitted unit on identical inputs, so the timings isolate
the scheduler:

  * scan/basic     — one lax.scan step per sorted slot (L steps).
  * scan/parallel  — rank-lockstep over groups (max-group-size steps).
  * chunked        — ceil(n_crossing / C) blocks, one batched LCA per
    block + arithmetic inner scan (this PR), lifting-climb distances.
  * chunked+euler  — same blocks, Euler-tour O(1)-LCA distance backend
    (the pipeline DEFAULT: use_euler_lca=True).

The scan schedules pay hundreds of per-slot steps of gather-bound tiny
ops on CPU, so they are timed with a single rep (they are the slow side
by orders of magnitude at 4K; rep noise cannot flip the comparison).

    PYTHONPATH=src python benchmarks/bench_phase1.py [--smoke]
"""
import argparse
import functools
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph import powergrid_like_graph
from repro.core.lca import LiftingTables, build_euler
from repro.core.marking import (GroupLayout, phase1_basic, phase1_chunked,
                                phase1_parallel)
from repro.core.pow2 import auto_chunk
from repro.core.sparsify import phase1_device


def _time(fn, reps):
    jax.block_until_ready(fn())  # warm the jit
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


@functools.partial(jax.jit, static_argnames=("engine", "k_cap", "chunk"))
def _mark_only(up, depth_t, su, sv, sbeta, layout, euler, engine,
               k_cap=32, chunk=32):
    """The MARK stage as a standalone jitted unit (inputs precomputed)."""
    t = LiftingTables(up=up, depth=depth_t)
    if engine == "basic":
        return phase1_basic(t, su, sv, sbeta, layout, k_cap=k_cap)
    if engine == "parallel":
        return phase1_parallel(t, su, sv, sbeta, layout, k_cap=k_cap)
    return phase1_chunked(t, su, sv, sbeta, layout, k_cap=k_cap,
                          chunk=chunk, euler=euler)


def run(quick: bool = False):
    reps = 2 if quick else 3
    n_side = 20 if quick else 64  # 400 vs 4096 nodes (case1 shape)
    g = powergrid_like_graph(n_side, 0.25, seed=101)
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    w = jnp.asarray(g.w, jnp.float32)

    # the common prefix, run once: everything up to the sorted layout
    d = phase1_device(u, v, w, g.n, schedule="chunked")
    jax.block_until_ready(d)
    up, depth_t = d["up"], d["depth_t"]
    perm = d["perm"]
    su, sv = u[perm], v[perm]
    sbeta = d["beta"][perm]
    crossing = d["crossing"]
    active = crossing[perm]
    m = int(g.m)
    layout = GroupLayout(
        perm=perm, gidx=d["gidx"],
        group_start=jnp.full((m,), jnp.int32(m)).at[d["gidx"]].min(
            jnp.arange(m, dtype=jnp.int32)),
        group_size=jnp.zeros((m,), jnp.int32).at[d["gidx"]].add(1),
        active=active, n_groups=d["n_groups"])
    # root is recoverable as the depth-0 node of the spanning tree
    root = jnp.argmin(jnp.where(depth_t == jnp.iinfo(jnp.int32).max,
                                jnp.iinfo(jnp.int32).max, depth_t))
    euler = build_euler(d["parent_t"], depth_t, root.astype(jnp.int32),
                        g.n)
    jax.block_until_ready(euler)
    chunk = auto_chunk(m)

    def mark(engine, use_euler=False):
        e = euler if use_euler else None
        return lambda: _mark_only(up, depth_t, su, sv, sbeta, layout, e,
                                  engine, chunk=chunk)

    # correctness first: all engines agree on this input
    ref = np.asarray(mark("basic")()[0])
    for eng, use_e in (("parallel", False), ("chunked", False),
                       ("chunked", True)):
        got = np.asarray(mark(eng, use_e)()[0])
        assert np.array_equal(ref, got), (eng, use_e)

    t_basic = _time(mark("basic"), 1)       # the slow side: 1 rep
    t_par = _time(mark("parallel"), 1)
    t_chk = _time(mark("chunked"), reps)
    t_eul = _time(mark("chunked", True), reps)  # the pipeline DEFAULT
    cfg = f"n={g.n} L={m} chunk={chunk}"
    return [
        ("phase1.mark.scan_basic_us", t_basic * 1e6, cfg),
        ("phase1.mark.scan_parallel_us", t_par * 1e6, cfg),
        ("phase1.mark.chunked_lifting_us", t_chk * 1e6, cfg),
        ("phase1.mark.chunked_euler_us", t_eul * 1e6,
         cfg + " (default)"),
        ("phase1.mark.speedup_vs_basic", 0.0, round(t_basic / t_eul, 2)),
        ("phase1.mark.speedup_vs_parallel", 0.0, round(t_par / t_eul, 2)),
        ("phase1.mark.euler_vs_lifting", 0.0, round(t_chk / t_eul, 2)),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / few reps (CI smoke job)")
    args = ap.parse_args()
    rows = run(quick=args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    sp = rows[4][2]
    print(f"chunked marking (default engine) is {sp}x the basic scan "
          f"({'WIN' if sp >= 2 else 'MISS'} vs the 2x bar)")
