"""Kernel-layer micro-benchmarks.

On this CPU host the Pallas kernels only run in interpret mode (Python
semantics — not a performance number), so wall-time rows time the jnp
reference path; kernel rows are single-call interpret sanity timings,
labelled as such.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sort import radix_argsort_u32
from repro.kernels import ref


def _t(fn, reps=3):
    out = fn()
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    s = 1024 if quick else 4096
    q = jnp.asarray(rng.standard_normal((1, s, 4, 64)), jnp.float32)
    qb = q.transpose(0, 2, 1, 3).reshape(4, s, 64)
    pos = jnp.arange(s, dtype=jnp.int32)
    f = jax.jit(lambda a: ref.flash_attention_ref(a, a, a, pos, pos,
                                                  causal=True))
    rows.append((f"kernels.attention_ref_s{s}", _t(lambda: f(qb)) * 1e6,
                 s))
    n = 65_536 if quick else 262_144
    keys = jnp.asarray(rng.integers(0, 2 ** 32, n, dtype=np.uint32))
    g = jax.jit(radix_argsort_u32)
    rows.append((f"kernels.radix_sort_n{n}", _t(lambda: g(keys)) * 1e6,
                 n))
    m1 = jnp.asarray(rng.integers(0, 2 ** 32, (n // 16, 2),
                                  dtype=np.uint32))
    h = jax.jit(lambda a, b: jnp.any(jnp.bitwise_and(a, b) != 0, axis=1))
    rows.append((f"kernels.bitmap_ref_n{n//16}",
                 _t(lambda: h(m1, m1)) * 1e6, n // 16))
    return rows
