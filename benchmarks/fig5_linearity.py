"""Fig. 5 analogue: LGRASS runtime vs graph size on random test cases.

The paper's claim is strict linearity as size scales. We time the device
pipeline (phase 1, fully-jitted) over a geometric size ladder and report
the least-squares exponent of log(time) vs log(edges) — linear means
exponent ~1. (Host recovery excluded: it is output-sensitive and tiny.)
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import random_connected_graph
from repro.core.sparsify import phase1_device


def _time_phase1(g, reps=2):
    # schedule pinned to the basic scan so the measured engine cannot
    # drift when pipeline defaults change (it did once: the default is
    # now the chunked scheduler); linearity of the default engine is
    # bench_phase1's business, this figure tracks the paper's basic
    # LGRASS trajectory across PRs
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    w = jnp.asarray(g.w, jnp.float32)

    def call():
        return phase1_device(u, v, w, g.n, 8, False, 10,
                             schedule="scan")

    jax.block_until_ready(call())  # compile + warmup
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(quick: bool = False):
    sizes = [2_000, 4_000, 8_000] if quick else [4_000, 8_000, 16_000,
                                                 32_000]
    rows = []
    logs = []
    for n in sizes:
        g = random_connected_graph(n, 2 * n, seed=n)
        t = _time_phase1(g, reps=1 if n >= 32_000 else 2)
        rows.append((f"fig5.lgrass_n{n}", t * 1e6, g.m))
        logs.append((np.log(g.m), np.log(t)))
    x = np.array([a for a, _ in logs])
    y = np.array([b for _, b in logs])
    slope = float(np.polyfit(x, y, 1)[0])
    rows.append(("fig5.scaling_exponent", 0.0, round(slope, 3)))
    return rows
