"""Closed-loop serving benchmark for `SparsifyService` (PR 6).

A seeded Poisson arrival process generates mixed-size traffic (three
graph families across three pow2 buckets, mixed explicit/None budgets).
The client is CLOSED-LOOP: it sleeps until each request's scheduled
arrival, submits the accumulated burst as one `sparsify` call, and
clocks completion when results are back on the host. Per-request
latency = completion - arrival, so queueing delay behind a slow chunk
is charged to every request waiting on it — exactly what the async
plane is supposed to shrink.

Modes: sync, async, async+donate, and (when >1 device is visible,
e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8)
async+donate+sharded. Warmup dispatches are excluded from timing;
every mode's results are parity-checked against per-graph
`lgrass_sparsify` before its numbers are reported.

Rows (benchmarks/run.py format): name, us_per_call = mean per-request
latency, derived = p50/p99 latency (ms) + graphs/sec + speedup vs sync.
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import numpy as np


def _traffic(n_requests: int, seed: int):
    """Seeded mixed-size request stream + Poisson arrival offsets (s)."""
    from repro.core.graph import (powergrid_like_graph,
                                  random_connected_graph, trivial_graph)

    rng = np.random.default_rng(seed)
    graphs, budgets = [], []
    for i in range(n_requests):
        kind = rng.integers(0, 10)
        if kind < 4:
            g = random_connected_graph(int(rng.integers(16, 28)), 24,
                                       seed=int(rng.integers(1 << 16)))
        elif kind < 7:
            g = random_connected_graph(int(rng.integers(34, 60)), 64,
                                       seed=int(rng.integers(1 << 16)))
        elif kind < 9:
            g = powergrid_like_graph(7, 0.5, seed=int(rng.integers(1 << 16)))
        else:
            g = trivial_graph()
        graphs.append(g)
        budgets.append(int(rng.integers(2, 9)) if rng.random() < 0.5
                       else None)
    # Poisson arrivals: exponential inter-arrival gaps
    gaps = rng.exponential(scale=1.0, size=n_requests)
    arrivals = np.cumsum(gaps)
    return graphs, budgets, arrivals


def _reference(graphs, budgets):
    from repro.core import lgrass_sparsify

    return [lgrass_sparsify(g, budget=b, parallel=False) if g.m else None
            for g, b in zip(graphs, budgets)]


def _check_parity(graphs, results, ref, mode: str) -> None:
    for k, (g, r) in enumerate(zip(graphs, results)):
        if g.m == 0:
            assert r.n_accepted == 0 and r.edge_mask.shape == (0,), (mode, k)
        elif not (np.array_equal(r.edge_mask, ref[k].edge_mask)
                  and r.n_accepted == ref[k].n_accepted):
            raise AssertionError(f"parity violation in mode={mode} at "
                                 f"request {k}")


def _closed_loop(svc, graphs, budgets, sched):
    """One closed-loop pass; returns (results, latencies (s), wall (s))."""
    results: List[object] = [None] * len(graphs)
    lat = np.zeros(len(graphs))
    t0 = time.perf_counter()
    i = 0
    while i < len(graphs):
        now = time.perf_counter() - t0
        if now < sched[i]:
            time.sleep(sched[i] - now)
        # submit every request that has arrived by now as one burst
        j = i + 1
        now = time.perf_counter() - t0
        while j < len(graphs) and sched[j] <= now:
            j += 1
        out = svc.sparsify(graphs[i:j], budget=budgets[i:j])
        done = time.perf_counter() - t0
        for k in range(i, j):
            results[k] = out[k - i]
            lat[k] = done - sched[k]
        i = j
    wall = time.perf_counter() - t0
    return results, lat, wall


def _run_mode(mode: str, graphs, budgets, arrivals, rate_hz: float,
              warm_sizes, warm_batches, warm_budgets, n_passes: int = 5):
    """Warm a service, run `n_passes` closed loops, report the
    median-wall pass (per-pass wall is tens of ms on a noisy CPU box;
    the median keeps one descheduled pass from deciding the row).
    Every pass's results are returned for parity checking."""
    from repro.serve.sparsify_service import SparsifyService

    # chunks of 4: the latency-oriented serving config. Per-chunk device
    # programs are then ~1-3ms on these request sizes, so the host-side
    # work async mode overlaps (staging fill, dispatch bookkeeping,
    # result scatter) is a real fraction of the chunk — which is exactly
    # the regime the async plane targets. With big chunks the program
    # dominates and every mode converges to the same device-bound wall.
    kw = dict(parallel=False, max_batch_size=4)
    if mode != "sync":
        kw["async_dispatch"] = True
    if "donate" in mode:
        kw["donate"] = True
    if "shard" in mode:
        from repro.core.distributed import batch_mesh
        kw["mesh"] = batch_mesh()
    svc = SparsifyService(**kw)
    svc.warmup(warm_sizes, batch_sizes=warm_batches, budgets=warm_budgets)

    sched = arrivals / rate_hz  # seconds from t0
    passes = [_closed_loop(svc, graphs, budgets, sched)
              for _ in range(n_passes)]
    walls = [p[2] for p in passes]
    results, lat, wall = passes[int(np.argsort(walls)[len(walls) // 2])]
    all_results = [p[0] for p in passes]
    return results, lat, wall, svc.stats, all_results


def run(quick: bool = False) -> List[Tuple[str, float, str]]:
    import jax

    # arrival rate is set far above service capacity (a single-graph
    # dispatch is ~2-4ms, so capacity is a few hundred Hz) — the serving
    # plane, not the arrival process, is the bottleneck; bursts then grow
    # until chunks fill and the async/donate overlap is what the numbers
    # see. The closed loop still charges queueing delay per request.
    n_requests = 32 if quick else 160
    rate_hz = 4000.0 if quick else 8000.0
    graphs, budgets, arrivals = _traffic(n_requests, seed=20260808)
    ref = _reference(graphs, budgets)

    # warm every bucket signature the stream can produce so on-path
    # compiles never pollute the timing (asserted below)
    warm_sizes = sorted({(g.n, g.m) for g in graphs})
    warm_batches = (1, 2, 4)  # every B_pad a max_batch_size=4 chunk can hit
    warm_budgets = [8]  # covers explicit budgets 2..8

    modes = ["sync", "async", "async_donate"]
    if len(jax.devices()) >= 2:
        modes.append("async_donate_shard")

    rows: List[Tuple[str, float, str]] = []
    sync_wall: Optional[float] = None
    for mode in modes:
        results, lat, wall, stats, all_results = _run_mode(
            mode, graphs, budgets, arrivals, rate_hz,
            warm_sizes, warm_batches, warm_budgets)
        for pass_results in all_results:
            _check_parity(graphs, pass_results, ref, mode)
        assert stats.n_on_path_compiles == 0, (
            f"{mode}: {stats.n_on_path_compiles} on-path compiles — "
            "warmup does not cover the traffic")
        if mode == "sync":
            sync_wall = wall
        gps = n_requests / wall
        speedup = sync_wall / wall if sync_wall else 1.0
        derived = (f"p50={np.percentile(lat, 50) * 1e3:.1f}ms "
                   f"p99={np.percentile(lat, 99) * 1e3:.1f}ms "
                   f"graphs_per_s={gps:.1f} speedup_vs_sync={speedup:.2f}x "
                   f"dispatches={stats.n_dispatches} "
                   f"pad={stats.padding_overhead:.2f}")
        rows.append((f"service.{mode}", float(np.mean(lat) * 1e6), derived))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small request count (CI)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(quick=args.smoke):
        print(f"{name},{us:.1f},{derived}")
