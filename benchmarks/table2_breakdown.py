"""Table 2 analogue: per-subroutine time breakdown of basic LGRASS
(EFF/BFS, MST, LCA+RES, SORT, MARK) on an official-style case."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bfs import bfs, effective_weights, select_root
from repro.core.graph import powergrid_like_graph
from repro.core.lca import build_lifting, lca_with_shortcut
from repro.core.marking import (build_group_layout, group_keys,
                                phase1_basic)
from repro.core.mst import boruvka_mst
from repro.core.resistance import (criticality, node_parent_inv_w,
                                   root_path_sums)
from repro.core.sort import sort_f32_desc_stable


def _t(fn):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return time.perf_counter() - t0, out


def run(quick: bool = False):
    side = 24 if quick else 64
    g = powergrid_like_graph(side, 0.25, seed=3)
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    w = jnp.asarray(g.w, jnp.float32)
    n = g.n
    rows = []

    t, root = _t(lambda: select_root(u, v, n))
    t_eff, (depth_g, _) = _t(lambda: bfs(u, v, n, root))
    t2, eff = _t(lambda: effective_weights(u, v, w, depth_g, n))
    rows.append((f"table2.EFF_n{n}", (t + t_eff + t2) * 1e6, g.m))

    t_sort1, perm = _t(lambda: sort_f32_desc_stable(eff))
    rank = jnp.zeros_like(perm).at[perm].set(
        jnp.arange(perm.shape[0], dtype=jnp.int32))
    t_mst, tree = _t(lambda: boruvka_mst(u, v, rank, n))
    rows.append((f"table2.MST_n{n}", (t_sort1 + t_mst) * 1e6, g.m))

    _, (depth_t, parent_t) = _t(lambda: bfs(u, v, n, root, edge_mask=tree))
    t_lift, tbl = _t(lambda: build_lifting(parent_t, depth_t, n))
    t_lca, elca = _t(lambda: lca_with_shortcut(tbl, root, u, v))
    rows.append((f"table2.LCA_n{n}", (t_lift + t_lca) * 1e6, g.m))

    inv_w = node_parent_inv_w(u, v, w, tree, parent_t, n)
    t_res, r = _t(lambda: root_path_sums(tbl, inv_w))
    t_crit, crit = _t(lambda: criticality(tbl, r, u, v, w, elca))
    rows.append((f"table2.RES_n{n}", (t_res + t_crit) * 1e6, g.m))

    hi, lo, crossing = group_keys(tbl, root, u, v, elca, ~tree)
    t_sort, layout = _t(lambda: build_group_layout(crit, hi, lo, crossing))
    rows.append((f"table2.SORT_n{n}", t_sort * 1e6, g.m))

    su, sv = u[layout.perm], v[layout.perm]
    beta = jnp.maximum(jnp.minimum(depth_t[u], depth_t[v])
                       - depth_t[elca], 1).astype(jnp.int32)
    sbeta = beta[layout.perm]
    t_mark, _ = _t(lambda: phase1_basic(tbl, su, sv, sbeta, layout, 8))
    rows.append((f"table2.MARK_n{n}", t_mark * 1e6, g.m))
    return rows
