"""Solver-free ER estimator: cost scaling and quality-vs-budget.

The estimator (`core/spectral_probe.py`) is k spmv rounds over P probe
vectors — O(k·P·m) flops, no factorisation, no dense anything — so its
cost must scale near-linearly in edges and linearly in probes. This
bench records both axes plus the knob the quality tiers actually buy
with them:

  * cost vs n   — fixed (P, k), random graphs with m = 2n edges at
    geometrically growing n; `derived` carries edges/µs and the
    step-to-step time ratio vs the size ratio (1.0 = perfectly linear);
  * cost vs P   — fixed n, probes swept geometrically; spmv work is
    shared across probes inside one dispatch, so growth should track P;
  * quality vs budget — at a dense-oracle-reachable size (n = 512),
    Spearman rank correlation of the estimated criticality ordering
    against the float64 pinv, per probe budget: the curve that justifies
    the P chosen by tests/test_spectral_probe.py (variance ~ sqrt(2/P));
  * sparsifier budget curve — at the largest swept n, the solver-free
    trace-similarity score of LGRASS sparsifiers across chord budgets,
    normalised by the full graph's score: the quality-vs-budget curve
    of tests/test_spectral_quality_scale.py, recorded as numbers.

    PYTHONPATH=src python benchmarks/bench_spectral.py [--smoke]
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph import random_connected_graph
from repro.core.resistance import probe_calibration_np
from repro.core.sparsify import lgrass_sparsify, phase1_device
from repro.core.spectral_probe import (probe_edge_resistance,
                                       trace_similarity)

N_ITERS = 32
N_PROBES = 16


def _time(fn, reps):
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(quick: bool = False):
    reps = 2 if quick else 4
    rows = []

    # --- cost vs n: m = 2n, P and k fixed -----------------------------
    sizes = [2_000, 8_000, 32_000] if quick else [10_000, 40_000, 160_000]
    t_prev = None
    for i, n in enumerate(sizes):
        g = random_connected_graph(n, n, seed=100 + i)

        def est():
            return jax.block_until_ready(probe_edge_resistance(
                g.u, g.v, g.w, g.n, n_probes=N_PROBES, n_iters=N_ITERS,
                seed=1))

        r = est()  # warm (compile per shape)
        assert np.isfinite(np.asarray(r)).all()
        t = _time(est, reps)
        ratio = ""
        if t_prev is not None:
            # time ratio per size ratio: 1.0 == perfectly linear
            ratio = f" step_ratio={t / t_prev / (n / n_prev):.2f}"
        rows.append((f"spectral.er_n{n}_p{N_PROBES}_k{N_ITERS}.us",
                     t * 1e6, f"edges_per_us={g.m / (t * 1e6):.1f}{ratio}"))
        t_prev, n_prev = t, n

    # --- cost vs probes: n fixed --------------------------------------
    n = sizes[1]
    g = random_connected_graph(n, n, seed=200)
    probe_sweep = [8, 32, 128]
    t8 = None
    for p in probe_sweep:
        def est_p():
            return jax.block_until_ready(probe_edge_resistance(
                g.u, g.v, g.w, g.n, n_probes=p, n_iters=N_ITERS, seed=1))

        est_p()
        t = _time(est_p, reps)
        t8 = t if t8 is None else t8
        rows.append((f"spectral.er_n{n}_probes{p}.us", t * 1e6,
                     f"vs_p{probe_sweep[0]}={t / t8:.2f}x"))

    # --- quality vs probe budget (dense-oracle size) ------------------
    gq = random_connected_graph(512, 1024, seed=300)
    d = jax.device_get(phase1_device(
        jnp.asarray(gq.u, jnp.int32), jnp.asarray(gq.v, jnp.int32),
        jnp.asarray(gq.w, jnp.float32), gq.n))
    off = ~d["tree_mask"].astype(bool)
    for p in ([16, 64] if quick else [16, 64, 256]):
        r_hat = np.asarray(probe_edge_resistance(
            gq.u, gq.v, gq.w, gq.n, n_probes=p, n_iters=64, seed=2))
        cal = probe_calibration_np(
            gq.n, gq.u, gq.v, gq.w, gq.u[off], gq.v[off], gq.w[off],
            r_hat[off])
        rows.append((f"spectral.quality_n512.p{p}", 0.0,
                     f"spearman_crit={cal['spearman_crit']:.3f} "
                     f"med_rel_err={cal['med_rel_err']:.3f}"))

    # --- sparsifier quality vs chord budget (solver-free score) -------
    gs = random_connected_graph(sizes[-1], sizes[-1], seed=400)
    r_hat = jnp.asarray(probe_edge_resistance(
        gs.u, gs.v, gs.w, gs.n, n_probes=N_PROBES, n_iters=N_ITERS,
        seed=3))
    wj = jnp.asarray(gs.w)
    s_full = float(trace_similarity(wj, r_hat))
    for budget in [0, 16, 64, 256]:
        res = lgrass_sparsify(gs, budget=max(budget, 1),
                              b_cap=max(64, budget))
        mask = res.tree_mask if budget == 0 else res.edge_mask
        s = float(trace_similarity(wj, r_hat, jnp.asarray(mask)))
        rows.append((f"spectral.budget_n{gs.n}.b{budget}", 0.0,
                     f"trace_frac={s / s_full:.5f}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / few reps (CI smoke job)")
    args = ap.parse_args()
    rows = run(quick=args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
