"""Table 3 analogue: baseline vs basic LGRASS vs parallel LGRASS.

The IPCC baseline took 22.9 min / 25.5 min / 37.1 h on the official cases;
our baseline re-implementation is already far faster (vectorised numpy),
so we report it on a reduced case plus the LGRASS variants on full-size
official-style cases. "basic" = sequential lax.scan greedy (Fig. 1b),
"parallel" = rank-lockstep greedy (Fig. 1c). On this 1-core CI host the
parallel schedule shows its *algorithmic* shape (fewer sequential steps),
not a wall-clock speedup — Table 3's 3.1x comes from real cores.
"""
import time

import numpy as np

from repro.core import baseline_sparsify, lgrass_sparsify
from repro.core.graph import powergrid_like_graph


def _time(fn, reps=3):
    fn()  # warmup / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(quick: bool = False):
    rows = []
    # baseline semantics on a reduced case (it is super-linear)
    gb = powergrid_like_graph(16 if quick else 24, 0.25, seed=1)
    tb = _time(lambda: baseline_sparsify(gb), reps=1)
    rows.append((f"table3.baseline_n{gb.n}", tb * 1e6, gb.m))

    sides = [16, 24] if quick else [64, 84, 127]   # official case sizes
    for i, side in enumerate(sides):
        g = powergrid_like_graph(side, 0.25, seed=side)
        t_basic = _time(
            lambda: lgrass_sparsify(g, k_cap=8, parallel=False,
                                    auto_lift_bound=True),
            reps=1 if side > 90 else 2)
        rows.append((f"table3.basic_lgrass_n{g.n}", t_basic * 1e6, g.m))
        if i == 0:
            # the rank-lockstep schedule trades span for lane-work
            # (R_max·G·K vs L·K): a *win* across chips/cores, a loss on
            # this 1-core host — timed once on the smallest case for the
            # record; the dry-run exercises it at 256/512 shards.
            t_par = _time(
                lambda: lgrass_sparsify(g, k_cap=8, parallel=True,
                                        auto_lift_bound=True), reps=1)
            rows.append((f"table3.lockstep_schedule_n{g.n}",
                         t_par * 1e6, g.m))
    return rows
