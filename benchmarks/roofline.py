"""Roofline table generator: reads the dry-run artifacts and emits the
§Roofline markdown (one row per arch × shape × mesh) plus summary rows
for benchmarks.run.

Memory term: the HLO-parsed byte count from the *CPU-compiled* module
over-counts TPU HBM traffic (the CPU backend fuses far less), so the
table's t_memory uses an analytic central model — parameter traffic
(FSDP re-gathers per microbatch × passes) + activation traffic (remat:
fwd + recompute + bwd) + cache traffic for decode — with the HLO number
kept as the upper bound column.
"""
import json
import os
from typing import Dict, List, Optional

ARTIFACTS = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "experiments",
                 "artifacts"))

HBM_BW = 819e9
PEAK = 197e12


def analytic_memory_bytes(rec: Dict) -> Optional[float]:
    """Per-device-per-step HBM traffic central estimate."""
    try:
        from repro.configs import ARCHS, SHAPES
    except ImportError:
        return None
    if rec.get("arch") not in ARCHS or rec.get("shape") not in SHAPES:
        return None
    cfg = ARCHS[rec["arch"]].padded_for_mesh(16)
    shape = SHAPES[rec["shape"]]
    chips = rec.get("chips", 256)
    tp = 16
    dp = chips // tp
    p_bytes = cfg.n_params() * 4.0 / tp     # full params per device (f32)
    b_loc = max(shape.global_batch // dp, 1)
    act_dtype = 2.0
    micro = max(rec.get("micro_batches", 1) or 1, 1)
    # ~20 layer-level tensors of (B,S,d) per block is a good central
    # estimate for transformer/SSD blocks
    act = (20.0 * cfg.n_layers * b_loc * shape.seq_len * cfg.d_model *
           act_dtype)
    if shape.kind == "train":
        # params read fwd+recompute+bwd per microbatch; grads+opt f32
        traffic = 3.0 * micro * p_bytes + 3.0 * act + \
            3.0 * cfg.n_params() * 4.0 / chips * 4
    elif shape.kind == "prefill":
        traffic = p_bytes + act / 3.0
    else:  # decode: params + full cache read per token
        cache = 0.0
        if cfg.n_kv_heads and cfg.attn_type == "gqa":
            hd = cfg.resolved_head_dim
            slots = min(shape.seq_len, cfg.sliding_window or
                        shape.seq_len)
            glob = len(cfg.global_layers) if cfg.sliding_window else \
                cfg.n_layers
            win = cfg.n_layers - glob
            cache = 2 * act_dtype * cfg.n_kv_heads * hd * (
                glob * shape.seq_len + win * slots)
        if cfg.attn_type == "mla":
            cache = act_dtype * cfg.n_layers * shape.seq_len * (
                cfg.kv_lora_rank + cfg.qk_rope_head_dim)
        if cfg.has_ssm:
            cache += (4.0 * cfg.n_layers * cfg.ssm_nheads *
                      cfg.ssm_headdim * cfg.ssm_state)
        # caches with >=4096 slots are 'model'-sharded (launch/specs.py)
        cache_div = tp if shape.seq_len >= 4096 else 1
        traffic = p_bytes + cache * b_loc / cache_div
    return float(traffic)


def load_records(outdir: str = ARTIFACTS) -> List[Dict]:
    recs = []
    if not os.path.isdir(outdir):
        return recs
    for f in sorted(os.listdir(outdir)):
        if f.endswith(".json"):
            with open(os.path.join(outdir, f)) as fh:
                recs.append(json.load(fh))
    return recs


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def terms(r: Dict) -> Optional[Dict]:
    """Roofline terms with the analytic memory model (falls back to the
    HLO number for non-arch cells like lgrass)."""
    if "skipped" in r:
        return None
    tc = r.get("t_compute_s", 0.0)
    tl = r.get("t_collective_s", 0.0)
    amem = analytic_memory_bytes(r)
    tm = (amem / HBM_BW) if amem else r.get("t_memory_s", 0.0)
    dom = max((("compute", tc), ("memory", tm), ("collective", tl)),
              key=lambda kv: kv[1])[0]
    frac = max(tc, 1e-30) / max(tc, tm, tl)
    return dict(t_compute=tc, t_memory=tm, t_collective=tl,
                t_memory_hlo_upper=r.get("t_memory_s", 0.0),
                dominant=dom, roofline_fraction=frac)


def markdown_table(recs: List[Dict], mesh: Optional[str] = None) -> str:
    lines = [
        "| cell | kind | t_compute | t_memory (analytic) | t_mem HLO-UB |"
        " t_collective | dominant | roofline-frac | useful-FLOP |"
        " HBM est |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if mesh and r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            lines.append(
                f"| {r['cell']} | — | — | — | — | — | SKIP | — | — |"
                f" {r['skipped'][:48]} |")
            continue
        t = terms(r)
        m = r.get("memory", {})
        hbm = m.get("hbm_estimate_bytes", m.get("temp_bytes", 0)) / 2 ** 30
        lines.append(
            "| {cell} | {kind} | {tc} | {tm} | {tmu} | {tl} | {dom} |"
            " {rf} | {uf} | {hbm:.1f}GiB |".format(
                cell=r["cell"], kind=r.get("kind", "?"),
                tc=_fmt_s(t["t_compute"]),
                tm=_fmt_s(t["t_memory"]),
                tmu=_fmt_s(t["t_memory_hlo_upper"]),
                tl=_fmt_s(t["t_collective"]),
                dom=t["dominant"],
                rf=f"{t['roofline_fraction']:.3f}",
                uf=f"{r.get('useful_flop_ratio', 0):.2f}",
                hbm=hbm))
    return "\n".join(lines)


def run(quick: bool = False):
    recs = load_records()
    rows = []
    n_ok = sum(1 for r in recs if "skipped" not in r)
    n_skip = sum(1 for r in recs if "skipped" in r)
    rows.append(("roofline.cells_compiled", 0.0, n_ok))
    rows.append(("roofline.cells_skipped_by_rule", 0.0, n_skip))
    for r in recs:
        t = terms(r)
        if t is None:
            continue
        dom_t = max(t["t_compute"], t["t_memory"], t["t_collective"])
        rows.append((f"roofline.{r['cell']}.dominant_term_s",
                     dom_t * 1e6, t["dominant"]))
    return rows


if __name__ == "__main__":
    recs = load_records()
    print(markdown_table(recs))
