"""Batched sparsification quickstart: many graphs, one device dispatch.

Builds a mixed-size request batch, serves it through the bucketing
`SparsifyService`, and verifies every result is bit-identical to the
single-graph path.

    PYTHONPATH=src python examples/batch_sparsify.py
"""
import time

import numpy as np

from repro.core import lgrass_sparsify
from repro.core.graph import powergrid_like_graph, random_connected_graph
from repro.serve.sparsify_service import SparsifyService


def main():
    rng = np.random.default_rng(0)
    graphs = []
    for i in range(12):
        if i % 3 == 0:
            graphs.append(powergrid_like_graph(int(rng.integers(5, 9)),
                                               0.3, seed=i))
        else:
            n = int(rng.integers(24, 64))
            graphs.append(random_connected_graph(n, 2 * n, seed=i))
    print(f"request batch: {len(graphs)} graphs, "
          f"n in [{min(g.n for g in graphs)}, {max(g.n for g in graphs)}], "
          f"L in [{min(g.m for g in graphs)}, {max(g.m for g in graphs)}]")

    svc = SparsifyService(parallel=False)  # basic schedule: CPU engine
    t0 = time.perf_counter()
    results = svc.sparsify(graphs)
    t_serve = time.perf_counter() - t0

    kept = [int(r.edge_mask.sum()) for r in results]
    print(f"served in {t_serve:.2f}s (incl. jit) with "
          f"{svc.stats.n_dispatches} device dispatch(es) over "
          f"{len(svc.stats.bucket_counts)} shape bucket(s); "
          f"padding overhead {svc.stats.padding_overhead:.0%}")
    for key, cnt in sorted(svc.stats.bucket_counts.items()):
        print(f"  bucket n<={key[0]:4d} L<={key[1]:4d}: {cnt} graph(s)")
    print(f"kept edges per graph: {kept}")

    for g, r in zip(graphs, results):
        assert np.array_equal(
            r.edge_mask, lgrass_sparsify(g, parallel=False).edge_mask
        )
    print("all results bit-identical to single-graph lgrass_sparsify")


if __name__ == "__main__":
    main()
