"""Serve a small model with batched requests: prefill a batch of prompts,
decode greedily with persistent KV/SSM caches.

    PYTHONPATH=src python examples/serve_lm.py --arch hymba-1.5b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.model import LM
from repro.serve.serve_step import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = LM(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.perf_counter()
    out = generate(model, params, prompts, args.max_new,
                   args.prompt_len + args.max_new + 1)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} (reduced) batch={args.batch} "
          f"prompt={args.prompt_len} new={args.max_new}")
    print(f"{args.batch * args.max_new} tokens in {dt:.2f}s "
          f"(incl. compile)")
    for i in range(min(2, args.batch)):
        print(f"  sample {i}: {np.asarray(out[i]).tolist()}")


if __name__ == "__main__":
    main()
