"""Quickstart: sparsify a power-grid-style graph with LGRASS and verify
the output is bit-identical to the baseline program's semantics.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import (baseline_sparsify, lgrass_sparsify,
                        powergrid_like_graph)


def main():
    # a ~1.6K-node power-grid-like case (official cases are 4K/7K/16K)
    g = powergrid_like_graph(40, 0.25, seed=0)
    print(f"graph: {g.n} nodes, {g.m} edges")

    # basic schedule: the single-core engine (the lockstep schedule is
    # for many lanes — see DESIGN.md §3 and the dry-run cells)
    t0 = time.perf_counter()
    result = lgrass_sparsify(g, k_cap=8, parallel=False,
                             auto_lift_bound=True)
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    result = lgrass_sparsify(g, k_cap=8, parallel=False,
                             auto_lift_bound=True)   # steady state
    t_lgrass = time.perf_counter() - t0
    print(f"LGRASS: kept {int(result.edge_mask.sum())}/{g.m} edges "
          f"({result.n_accepted} off-tree) in {t_lgrass*1e3:.1f} ms "
          f"steady-state ({t_compile:.1f}s incl. first-call jit; "
          f"{result.n_groups} marking groups)")

    t0 = time.perf_counter()
    base = baseline_sparsify(g)
    t_base = time.perf_counter() - t0
    print(f"baseline semantics (host python/numpy): {t_base*1e3:.1f} ms")

    identical = np.array_equal(base.edge_mask, result.edge_mask)
    print(f"outputs identical: {identical}")
    assert identical


if __name__ == "__main__":
    main()
