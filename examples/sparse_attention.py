"""Beyond-paper demo: LGRASS as a long-context attention-mask planner.

Builds a block graph over a long sequence, runs the exact LGRASS pipeline
on it, and compares block-sparse attention (LGRASS mask) against dense
attention — mask density and output error on the locality-structured part.

    PYTHONPATH=src python examples/sparse_attention.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.sparse.attention_graph import (block_sparse_attention,
                                          plan_block_mask)


def main():
    rng = np.random.default_rng(0)
    B, S, H, D = 1, 1024, 4, 64
    block = 32
    nb = S // block

    # token stream with locality + a few long-range dependencies
    x = rng.standard_normal((B, S, H * D)).astype(np.float32)
    x[:, 700:732] += x[:, 100:132] * 2.0  # long-range copy structure

    feats = x[0].reshape(nb, block, -1).mean(1)
    plan = plan_block_mask(feats, keep_frac=0.3, window=2)
    density = plan.mask.sum() / (nb * (nb + 1) / 2)
    print(f"{nb}x{nb} block mask: kept {plan.kept_edges}/{plan.total_edges}"
          f" graph edges -> causal mask density {density:.2%}")

    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)

    sparse = block_sparse_attention(q, k, v, jnp.asarray(plan.mask), block)

    # how much of the *dense* attention probability mass the mask covers
    scale = D ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    causal = jnp.tril(jnp.ones((S, S), bool))
    p_dense = jax.nn.softmax(jnp.where(causal, scores, -1e9), -1)
    tok_mask = jnp.repeat(jnp.repeat(jnp.asarray(plan.mask), block, 0),
                          block, 1) & causal
    covered = float((p_dense * tok_mask[None, None]).sum() / p_dense.sum())
    print(f"attention mass covered by LGRASS mask: {covered:.1%} "
          f"at {density:.1%} of the compute")

    # connectivity guarantee: the kept block graph (incl. spanning tree)
    # is connected, so information can propagate between any two blocks
    adj = plan.mask | plan.mask.T
    seen = np.zeros(nb, bool)
    seen[0] = True
    frontier = [0]
    while frontier:
        nxt = []
        for x in frontier:
            for y in np.where(adj[x])[0]:
                if not seen[y]:
                    seen[y] = True
                    nxt.append(int(y))
        frontier = nxt
    print(f"block graph connected (spanning-tree guarantee): "
          f"{bool(seen.all())}")


if __name__ == "__main__":
    main()
