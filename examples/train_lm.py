"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the fault-tolerant trainer (checkpoint/restart + deterministic data).

    PYTHONPATH=src python examples/train_lm.py --steps 300
(defaults to 20 steps so the demo finishes quickly on 1 CPU core)
"""
import argparse
import dataclasses

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.ft.elastic import FaultConfig
from repro.models.model import LM
from repro.optim.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: a scaled-down mamba2 (the paper-assigned SSM family)
    cfg = dataclasses.replace(
        get_arch("mamba2-370m"),
        n_layers=16, d_model=768, vocab_size=32000,
        ssm_state=64, ssm_chunk=64, dtype="float32", remat=False)
    model = LM(cfg)
    print(f"model: {cfg.n_params()/1e6:.1f}M params")

    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                    global_batch=4, seed=0))
    trainer = Trainer(
        model, data,
        OptConfig(peak_lr=1e-3, warmup_steps=max(args.steps // 10, 1),
                  total_steps=args.steps),
        TrainerConfig(total_steps=args.steps, log_every=5),
        args.ckpt_dir,
        fault_cfg=FaultConfig(ckpt_every=50),
    )
    out = trainer.run()
    h = out["history"]
    print(f"loss: {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} over "
          f"{len(h)} steps")


if __name__ == "__main__":
    main()
