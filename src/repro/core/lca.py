"""Batched LCA via binary lifting (TPU adaptation of LGRASS §3.2/§4.3).

The paper uses an online sequential LCA (Schieber–Vishkin flavoured) plus
the root-subtree shortcut. A sequential O(1)-per-query LCA is the wrong
shape for a TPU; the data-parallel equivalent is binary lifting — all L
queries are answered simultaneously with O(log depth) gathers each, which
is a handful of fully-vectorised rounds over (L,) arrays. The paper's
root-subtree shortcut *is* kept: queries whose endpoints live in different
root subtrees return `root` without climbing (`subroot` below), which in
the IPCC inputs answers the majority of queries in O(1).

Tables are (LOG, n) int32 in HBM; every query round is a gather — exactly
the access pattern TPUs stream well.

Two query engines live here:

  * binary lifting (`LiftingTables`, `lca`) — O(log depth) gathers per
    query, cheap O(n log n) construction (one scan).
  * Euler tour + sparse-table RMQ (`EulerLCA`, `lca_euler`) — O(1)
    gathers per query after an O(n log n) device-side construction: the
    tour is derived from per-arc successor pointers ranked by pointer
    doubling (the classic list-ranking formulation, fully vectorised),
    and range-minimum queries over the tour's depth sequence answer LCA
    with two sparse-table gathers. Worth building once per graph when a
    stage issues many batched distance queries (the chunked phase-1
    marking scheduler's cover tables).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.pow2 import log2_ceil as _log2_ceil


class LiftingTables(NamedTuple):
    up: jax.Array     # (LOG, n) int32 — 2^k-th ancestor (root loops to itself)
    depth: jax.Array  # (n,) int32


@functools.partial(jax.jit, static_argnames=("n", "levels"))
def build_lifting(parent: jax.Array, depth: jax.Array, n: int,
                  levels: int | None = None) -> LiftingTables:
    """levels: optional depth bound — must satisfy 2^levels > max(depth).
    The default ceil(log2(n+1)) is always safe; a measured bound shrinks
    every LCA climb proportionally (§Perf 'lift_bound': tree depth of the
    power-grid/random cases is O(sqrt N)/O(log N), far below N)."""
    log = levels if levels is not None else _log2_ceil(n + 1)
    up0 = jnp.where(parent < 0, jnp.arange(n, dtype=jnp.int32), parent)

    def step(carry, _):
        nxt = carry[carry]
        return nxt, carry

    _, ups = jax.lax.scan(step, up0, None, length=log)
    return LiftingTables(up=ups, depth=depth)


@jax.jit
def kth_ancestor(t: LiftingTables, node: jax.Array, k: jax.Array) -> jax.Array:
    """Vectorised: ancestor `k` hops above `node` (clamped at root).

    The climb is unrolled over the (static) level count: every `up[i]`
    is a static slice, so XLA sees LOG plain gathers instead of a loop
    of dynamic-slice + gather — ~3x faster on gather-bound backends and
    bit-identical.
    """
    log = t.up.shape[0]
    cur = node
    for i in range(log):
        bit = (k >> i) & 1
        cur = jnp.where(bit == 1, t.up[i][cur], cur)
    return cur


@jax.jit
def lca(t: LiftingTables, a: jax.Array, b: jax.Array) -> jax.Array:
    """Vectorised LCA for query arrays a, b (same shape)."""
    log = t.up.shape[0]
    da, db = t.depth[a], t.depth[b]
    # lift the deeper endpoint
    a2 = kth_ancestor(t, a, jnp.maximum(da - db, 0))
    b2 = kth_ancestor(t, b, jnp.maximum(db - da, 0))
    for i in range(log):
        k = log - 1 - i
        ua, ub = t.up[k][a2], t.up[k][b2]
        jump = (a2 != b2) & (ua != ub)
        a2 = jnp.where(jump, ua, a2)
        b2 = jnp.where(jump, ub, b2)
    return jnp.where(a2 == b2, a2, t.up[0][a2])


@jax.jit
def tree_distance(t: LiftingTables, a: jax.Array, b: jax.Array) -> jax.Array:
    w = lca(t, a, b)
    return t.depth[a] + t.depth[b] - 2 * t.depth[w]


@jax.jit
def tree_distance_with_lca(
    t: LiftingTables, a: jax.Array, b: jax.Array, w: jax.Array
) -> jax.Array:
    """Distance when the LCA is already known (saves the climb)."""
    return t.depth[a] + t.depth[b] - 2 * t.depth[w]


@jax.jit
def subroot(t: LiftingTables, node: jax.Array) -> jax.Array:
    """Ancestor at depth 1 (the root-subtree id); root maps to itself.

    This implements the paper's LCA shortcut: two nodes in different root
    subtrees have LCA == root, no climb needed.
    """
    d = t.depth[node]
    return kth_ancestor(t, node, jnp.maximum(d - 1, 0))


@jax.jit
def lca_with_shortcut(
    t: LiftingTables, root: jax.Array, a: jax.Array, b: jax.Array
) -> jax.Array:
    """LGRASS §3.2: if a, b sit in different root subtrees, LCA = root."""
    sa, sb = subroot(t, a), subroot(t, b)
    different = sa != sb
    full = lca(t, a, b)
    return jnp.where(different, root, full)


class EulerLCA(NamedTuple):
    """Euler tour + sparse-table RMQ — O(1) gathers per LCA query.

    Sized for a tree over <= n nodes: P = 2n - 1 tour positions. With a
    padded node range (batched pipeline) only the reachable tree is
    toured; trailing positions carry INT32_MAX depth so range minima
    never select them.
    """

    tour: jax.Array   # (P,) int32 — node at each tour position
    dseq: jax.Array   # (P,) int32 — depth along the tour (INF past the end)
    first: jax.Array  # (n,) int32 — first tour position of each node
    table: jax.Array  # (LOGP, P) int32 — position of the depth min in
    #                   [i, i + 2^k) (clamped at the tour end)
    depth: jax.Array  # (n,) int32 — node depths (distance arithmetic)


def tables_from_tour(tour: jax.Array, T: jax.Array, depth: jax.Array,
                     n: int) -> EulerLCA:
    """EulerLCA tables from an already-materialised tour.

    `tour` is the (P = 2n-1,) node sequence with positions 0..T real
    (T = tour length - 1); any valid Euler tour of the (sub)tree works —
    the range minimum between two first occurrences is the unique LCA
    node regardless of child visit order. Shared by `build_euler` and
    `bfs.root_tree_euler`, so there is exactly ONE definition of the
    table layout `lca_euler` queries.
    """
    P = 2 * n - 1
    INF = jnp.iinfo(jnp.int32).max
    piota = jnp.arange(P, dtype=jnp.int32)
    real = piota <= T  # positions 0..T hold the tour (length T + 1)
    dseq = jnp.where(real, depth[tour], INF)
    first = jnp.full((n,), P - 1, jnp.int32).at[
        jnp.where(real, tour, n)].min(piota, mode="drop")
    tabs = [piota]
    for k in range(1, _log2_ceil(P) + 1 if P > 1 else 1):
        h = 1 << (k - 1)
        prev = tabs[-1]
        other = prev[jnp.minimum(piota + h, P - 1)]
        tabs.append(jnp.where(dseq[other] < dseq[prev], other, prev))
    return EulerLCA(tour=tour, dseq=dseq, first=first,
                    table=jnp.stack(tabs), depth=depth)


@functools.partial(jax.jit, static_argnames=("n",))
def build_euler(parent: jax.Array, depth: jax.Array, root: jax.Array,
                n: int) -> EulerLCA:
    """Build the Euler-tour LCA tables on device.

    parent/depth: tree BFS outputs ((n,) int32, parent < 0 for the root
    and for unreachable padding nodes — only the reachable tree is
    toured). The tour is the node sequence of a DFS that orders children
    by ascending id; it is materialised without any sequential DFS:

      1. per-arc successor pointers (enter-first-child / advance-to-next-
         sibling / retreat-to-parent) from two scatter passes over the
         (parent, id)-sorted child list,
      2. arc positions by pointer-doubling list ranking (log rounds of
         gathers over the 2n arc slots),
      3. one scatter builds the node sequence; a scatter-min gives each
         node's first occurrence,
      4. a sparse table of range-depth-min positions over the tour.
    """
    from repro.core.sort import radix_argsort_u64pair

    P = 2 * n - 1
    INF = jnp.iinfo(jnp.int32).max
    nodes = jnp.arange(n, dtype=jnp.int32)
    valid_c = parent >= 0

    # -- 1. successor pointers ------------------------------------------
    # children sorted by (parent, id); invalid entries sort last
    hi = jnp.where(valid_c, parent.astype(jnp.uint32),
                   jnp.uint32(0xFFFFFFFF))
    S = radix_argsort_u64pair(hi, nodes.astype(jnp.uint32))
    Sv = valid_c[S]
    Sp = jnp.where(Sv, parent[S], -1)
    is_first = Sv & ((nodes == 0) | (Sp != jnp.roll(Sp, 1)))
    first_child = jnp.full((n,), -1, jnp.int32).at[
        jnp.where(is_first, Sp, n)].set(S, mode="drop")
    has_next = (nodes < n - 1) & Sv & (Sp == jnp.roll(Sp, -1))
    next_sib = jnp.full((n,), -1, jnp.int32).at[
        jnp.where(has_next, S, n)].set(jnp.roll(S, -1), mode="drop")

    # arc ids: down-arc of c (parent -> c) is c; up-arc (c -> parent) is
    # n + c. After entering c: descend to its first child, else climb
    # back. After leaving c: advance to its next sibling, else keep
    # climbing; the up-arc of the root's last child terminates the tour
    # (successor = itself, the list-ranking sentinel).
    arc_ids = jnp.arange(2 * n, dtype=jnp.int32)
    succ_down = jnp.where(first_child >= 0, first_child, n + nodes)
    at_end = (parent == root) & (next_sib < 0)
    succ_up = jnp.where(
        next_sib >= 0, next_sib,
        jnp.where(at_end, n + nodes, n + jnp.maximum(parent, 0)),
    )
    arc_valid = jnp.concatenate([valid_c, valid_c])
    succ = jnp.where(arc_valid,
                     jnp.concatenate([succ_down, succ_up]), arc_ids)

    # -- 2. list ranking by pointer doubling ----------------------------
    d = jnp.where(succ != arc_ids, 1, 0).astype(jnp.int32)
    nxt = succ
    for _ in range(_log2_ceil(2 * n) + 1):
        d = d + d[nxt]
        nxt = nxt[nxt]
    start = jnp.maximum(first_child[root], 0)  # root's first down-arc
    T = jnp.where(first_child[root] >= 0, d[start] + 1, 0)  # tour arcs
    pos = T - 1 - d  # pos[start] == 0; invalid arcs masked below

    # -- 3. node sequence -----------------------------------------------
    heads = jnp.concatenate([nodes, jnp.maximum(parent, 0)])
    wpos = jnp.where(arc_valid, pos + 1, P)
    tour = (jnp.zeros((P,), jnp.int32).at[0].set(root)
            .at[wpos].set(heads, mode="drop"))

    # -- 4. depth sequence, first occurrences, sparse RMQ table ---------
    return tables_from_tour(tour, T, depth, n)


@jax.jit
def lca_euler(e: EulerLCA, a: jax.Array, b: jax.Array) -> jax.Array:
    """Vectorised LCA in O(1) gathers per query (any query shape)."""
    logp, P = e.table.shape
    l = jnp.minimum(e.first[a], e.first[b])
    r = jnp.maximum(e.first[a], e.first[b])
    span = r - l + 1
    # floor(log2(span)) without clz: count the powers of two <= span
    k = jnp.zeros_like(span)
    for j in range(1, logp):
        k = k + (span >= (1 << j)).astype(span.dtype)
    flat = e.table.reshape(-1)
    i1 = flat[k * P + l]
    i2 = flat[k * P + (r + 1 - jnp.left_shift(jnp.int32(1), k))]
    w = jnp.where(e.dseq[i2] < e.dseq[i1], i2, i1)
    return e.tour[w]


@jax.jit
def tree_distance_euler(e: EulerLCA, a: jax.Array,
                        b: jax.Array) -> jax.Array:
    w = lca_euler(e, a, b)
    return e.depth[a] + e.depth[b] - 2 * e.depth[w]
