"""Batched LCA via binary lifting (TPU adaptation of LGRASS §3.2/§4.3).

The paper uses an online sequential LCA (Schieber–Vishkin flavoured) plus
the root-subtree shortcut. A sequential O(1)-per-query LCA is the wrong
shape for a TPU; the data-parallel equivalent is binary lifting — all L
queries are answered simultaneously with O(log depth) gathers each, which
is a handful of fully-vectorised rounds over (L,) arrays. The paper's
root-subtree shortcut *is* kept: queries whose endpoints live in different
root subtrees return `root` without climbing (`subroot` below), which in
the IPCC inputs answers the majority of queries in O(1).

Tables are (LOG, n) int32 in HBM; every query round is a gather — exactly
the access pattern TPUs stream well.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.pow2 import log2_ceil as _log2_ceil


class LiftingTables(NamedTuple):
    up: jax.Array     # (LOG, n) int32 — 2^k-th ancestor (root loops to itself)
    depth: jax.Array  # (n,) int32


@functools.partial(jax.jit, static_argnames=("n", "levels"))
def build_lifting(parent: jax.Array, depth: jax.Array, n: int,
                  levels: int | None = None) -> LiftingTables:
    """levels: optional depth bound — must satisfy 2^levels > max(depth).
    The default ceil(log2(n+1)) is always safe; a measured bound shrinks
    every LCA climb proportionally (§Perf 'lift_bound': tree depth of the
    power-grid/random cases is O(sqrt N)/O(log N), far below N)."""
    log = levels if levels is not None else _log2_ceil(n + 1)
    up0 = jnp.where(parent < 0, jnp.arange(n, dtype=jnp.int32), parent)

    def step(carry, _):
        nxt = carry[carry]
        return nxt, carry

    _, ups = jax.lax.scan(step, up0, None, length=log)
    return LiftingTables(up=ups, depth=depth)


@jax.jit
def kth_ancestor(t: LiftingTables, node: jax.Array, k: jax.Array) -> jax.Array:
    """Vectorised: ancestor `k` hops above `node` (clamped at root).

    The climb is unrolled over the (static) level count: every `up[i]`
    is a static slice, so XLA sees LOG plain gathers instead of a loop
    of dynamic-slice + gather — ~3x faster on gather-bound backends and
    bit-identical.
    """
    log = t.up.shape[0]
    cur = node
    for i in range(log):
        bit = (k >> i) & 1
        cur = jnp.where(bit == 1, t.up[i][cur], cur)
    return cur


@jax.jit
def lca(t: LiftingTables, a: jax.Array, b: jax.Array) -> jax.Array:
    """Vectorised LCA for query arrays a, b (same shape)."""
    log = t.up.shape[0]
    da, db = t.depth[a], t.depth[b]
    # lift the deeper endpoint
    a2 = kth_ancestor(t, a, jnp.maximum(da - db, 0))
    b2 = kth_ancestor(t, b, jnp.maximum(db - da, 0))
    for i in range(log):
        k = log - 1 - i
        ua, ub = t.up[k][a2], t.up[k][b2]
        jump = (a2 != b2) & (ua != ub)
        a2 = jnp.where(jump, ua, a2)
        b2 = jnp.where(jump, ub, b2)
    return jnp.where(a2 == b2, a2, t.up[0][a2])


@jax.jit
def tree_distance(t: LiftingTables, a: jax.Array, b: jax.Array) -> jax.Array:
    w = lca(t, a, b)
    return t.depth[a] + t.depth[b] - 2 * t.depth[w]


@jax.jit
def tree_distance_with_lca(
    t: LiftingTables, a: jax.Array, b: jax.Array, w: jax.Array
) -> jax.Array:
    """Distance when the LCA is already known (saves the climb)."""
    return t.depth[a] + t.depth[b] - 2 * t.depth[w]


@jax.jit
def subroot(t: LiftingTables, node: jax.Array) -> jax.Array:
    """Ancestor at depth 1 (the root-subtree id); root maps to itself.

    This implements the paper's LCA shortcut: two nodes in different root
    subtrees have LCA == root, no climb needed.
    """
    d = t.depth[node]
    return kth_ancestor(t, node, jnp.maximum(d - 1, 0))


@jax.jit
def lca_with_shortcut(
    t: LiftingTables, root: jax.Array, a: jax.Array, b: jax.Array
) -> jax.Array:
    """LGRASS §3.2: if a, b sit in different root subtrees, LCA = root."""
    sa, sb = subroot(t, a), subroot(t, b)
    different = sa != sb
    full = lca(t, a, b)
    return jnp.where(different, root, full)
