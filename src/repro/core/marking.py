"""Edge marking — LGRASS §3.1 + §4.2, the paper's core contribution.

The baseline marks edges with an O(N^2 L) triple loop (Alg. 1). LGRASS's
insight is twofold:

  1. *Node* marks instead of *edge* marks (Alg. 2/3): an accepted edge
     (u, v) with ball radius beta covers candidate (x, y) iff x and y lie
     in the paired balls B(u, beta) / B(v, beta).
  2. Crossing edges only interact within the same LCA (Lemma 3.1/3.2), so
     the greedy is partitioned into independent per-LCA subtasks, with
     root-LCA edges further split by their (subtree, subtree) pair — the
     paper's two-step mapping F(u, v) (§4.2).

TPU adaptation: instead of per-thread dynamic task queues we keep a
bounded table of accepted edges per group, (G, K) in HBM, and evaluate the
cover test *analytically* — dist(x, u_j) <= beta_j via batched LCA — which
replaces ball materialisation (pointer chasing) with dense gathers. Two
schedules are provided:

  * `phase1_basic`    — one lax.scan over edges in global criticality
    order (the paper's "basic LGRASS", Fig. 1b).
  * `phase1_parallel` — rank-lockstep over groups: at step r every group
    processes its r-th edge simultaneously (the paper's parallel edge
    marking, Fig. 2, mapped from thread-parallel to lane-parallel).

Groups whose accepted count exceeds K overflow; the host recovery stage
(recovery.py) re-checks those exactly, so K is a performance knob, never a
correctness knob.

Non-crossing edges are excluded here and replayed in recovery (Alg. 6),
exactly as the paper keeps that stage sequential (Fig. 1c).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.lca import LiftingTables, kth_ancestor, lca, subroot
from repro.core.sort import (
    float32_sort_key,
    radix_argsort_u32,
    radix_argsort_u64pair,
    sort_f32_desc_stable,
)

UMAX = jnp.uint32(0xFFFFFFFF)


class GroupLayout(NamedTuple):
    perm: jax.Array         # (L,) int32 — edge ids sorted by (group, crit-rank)
    gidx: jax.Array         # (L,) int32 — dense group index per sorted slot
    group_start: jax.Array  # (L,) int32 — first sorted slot of each group
    group_size: jax.Array   # (L,) int32
    active: jax.Array       # (L,) bool  — sorted slot holds a crossing edge
    n_groups: jax.Array     # scalar int32 (incl. possibly one inactive tail)


@functools.partial(jax.jit, static_argnames=())
def group_keys(
    t: LiftingTables,
    root: jax.Array,
    u: jax.Array,
    v: jax.Array,
    edge_lca: jax.Array,
    is_offtree: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The paper's two-step partition key F(u, v) as a (hi, lo) uint32 pair.

    hi = 0, lo = lca                      if lca != root
    hi = s1 + 1, lo = s2                  if lca == root (crossing)
    (UMAX, UMAX)                          inactive (tree / non-crossing)

    where s1 >= s2 are the compact root-subtree indices of u, v. Using a
    key *pair* instead of N + 1 + C(s1, 2) + s2 avoids the paper's int
    overflow at large root degree while keeping the identical partition.
    """
    n = t.depth.shape[0]
    crossing = is_offtree & (edge_lca != u) & (edge_lca != v)
    is_child = t.depth == 1
    child_rank = jnp.cumsum(is_child.astype(jnp.int32)) - 1
    s_u = child_rank[subroot(t, u)]
    s_v = child_rank[subroot(t, v)]
    s1 = jnp.maximum(s_u, s_v).astype(jnp.uint32)
    s2 = jnp.minimum(s_u, s_v).astype(jnp.uint32)
    at_root = edge_lca == root
    hi = jnp.where(at_root, s1 + 1, 0).astype(jnp.uint32)
    lo = jnp.where(at_root, s2, edge_lca.astype(jnp.uint32))
    hi = jnp.where(crossing, hi, UMAX)
    lo = jnp.where(crossing, lo, UMAX)
    return hi, lo, crossing


@jax.jit
def build_group_layout(
    crit: jax.Array,
    hi: jax.Array,
    lo: jax.Array,
    crossing: jax.Array,
    edge_valid: jax.Array | None = None,
) -> GroupLayout:
    """Sort edges by (group, criticality desc, id asc); derive group spans.

    edge_valid: optional (L,) padding mask (batched pipeline). Padding
    edges are forced out of every crossing group: they land in the
    inactive (UMAX, UMAX) tail group together with tree / non-crossing
    edges, where `active` is False, so phase 1 never inspects them and
    the dense group indices of real crossing groups are unchanged.
    """
    if edge_valid is not None:
        crossing = crossing & edge_valid
    m = crit.shape[0]
    p1 = sort_f32_desc_stable(jnp.where(crossing, crit, -jnp.inf))
    p2 = radix_argsort_u64pair(hi[p1], lo[p1])  # stable => keeps crit order
    perm = p1[p2]
    sh, sl = hi[perm], lo[perm]
    first = jnp.zeros((m,), dtype=bool).at[0].set(True)
    bnd = first | (sh != jnp.roll(sh, 1)) | (sl != jnp.roll(sl, 1))
    gidx = jnp.cumsum(bnd.astype(jnp.int32)) - 1
    group_start = jnp.full((m,), jnp.int32(m)).at[gidx].min(
        jnp.arange(m, dtype=jnp.int32)
    )
    group_size = jnp.zeros((m,), jnp.int32).at[gidx].add(1)
    active = crossing[perm]
    return GroupLayout(
        perm=perm,
        gidx=gidx,
        group_start=group_start,
        group_size=group_size,
        active=active,
        n_groups=gidx[-1] + 1,
    )


def _ball_pair_covered(
    t: LiftingTables,
    x: jax.Array,
    y: jax.Array,
    row_u: jax.Array,
    row_v: jax.Array,
    row_b: jax.Array,
    cnt: jax.Array,
) -> jax.Array:
    """Paired-ball cover test against a (…, K) accepted-edge table.

    covered <=> exists j < cnt:
        (d(x,u_j) <= b_j and d(y,v_j) <= b_j) or
        (d(x,v_j) <= b_j and d(y,u_j) <= b_j)

    Distances are tree hop distances via batched LCA — this is Alg. 3's
    check, evaluated analytically instead of via materialised ball sets.
    """
    k = row_u.shape[-1]
    xb = jnp.broadcast_to(x[..., None], row_u.shape)
    yb = jnp.broadcast_to(y[..., None], row_u.shape)

    def dist(a, b):
        w = lca(t, a, b)
        return t.depth[a] + t.depth[b] - 2 * t.depth[w]

    dxu = dist(xb, row_u)
    dxv = dist(xb, row_v)
    dyu = dist(yb, row_u)
    dyv = dist(yb, row_v)
    pair = ((dxu <= row_b) & (dyv <= row_b)) | ((dxv <= row_b) & (dyu <= row_b))
    valid = jnp.arange(k, dtype=jnp.int32) < cnt[..., None]
    return jnp.any(pair & valid, axis=-1)


class Phase1Result(NamedTuple):
    accept: jax.Array          # (L,) bool — per *sorted slot*
    group_overflow: jax.Array  # (L,) bool — per dense group index


@jax.jit
def phase1_edge_views(
    perm: jax.Array,
    gidx: jax.Array,
    accept_sorted: jax.Array,
    group_overflow: jax.Array,
    crossing: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Scatter phase-1's sorted-slot outputs back to edge-id order.

    The recovery stage consumes per-edge views: the phase-1 accept
    decision, the dense group index (-1 for anything that is not a
    crossing edge — tree, non-crossing, padding), and the initial dirty
    set (every crossing edge of an overflowed group). This is the glue
    between MARK and REC; the host tail computes the same three arrays
    in numpy (`_recovery_tail`), asserted equal by the parity tests.
    """
    L = perm.shape[0]
    accept_by_edge = jnp.zeros((L,), bool).at[perm].set(accept_sorted)
    group_of_edge = jnp.full((L,), -1, jnp.int32).at[perm].set(
        gidx.astype(jnp.int32)
    )
    group_of_edge = jnp.where(crossing, group_of_edge, -1)
    dirty0 = jnp.zeros((L,), bool).at[perm].set(
        group_overflow[gidx] & crossing[perm]
    )
    return accept_by_edge, group_of_edge, dirty0


@functools.partial(jax.jit, static_argnames=("k_cap",))
def phase1_basic(
    t: LiftingTables,
    su: jax.Array,
    sv: jax.Array,
    sbeta: jax.Array,
    layout: GroupLayout,
    k_cap: int = 32,
) -> Phase1Result:
    """Sequential greedy (basic LGRASS): one lax.scan over sorted slots."""
    m = su.shape[0]
    acc_u = jnp.zeros((m, k_cap), jnp.int32)
    acc_v = jnp.zeros((m, k_cap), jnp.int32)
    acc_b = jnp.full((m, k_cap), -1, jnp.int32)
    cnt = jnp.zeros((m,), jnp.int32)
    ovf = jnp.zeros((m,), bool)

    def step(carry, i):
        acc_u, acc_v, acc_b, cnt, ovf = carry
        g = layout.gidx[i]
        act = layout.active[i]
        x = jnp.where(act, su[i], 0)
        y = jnp.where(act, sv[i], 0)
        cov = _ball_pair_covered(t, x, y, acc_u[g], acc_v[g], acc_b[g], cnt[g])
        accept = act & ~cov
        full = cnt[g] >= k_cap
        ovf = ovf.at[g].set(ovf[g] | (accept & full))
        slot = jnp.minimum(cnt[g], k_cap - 1)
        store = accept & ~full
        acc_u = acc_u.at[g, slot].set(jnp.where(store, x, acc_u[g, slot]))
        acc_v = acc_v.at[g, slot].set(jnp.where(store, y, acc_v[g, slot]))
        acc_b = acc_b.at[g, slot].set(
            jnp.where(store, sbeta[i], acc_b[g, slot])
        )
        cnt = cnt.at[g].add(store.astype(jnp.int32))
        return (acc_u, acc_v, acc_b, cnt, ovf), accept

    (acc_u, acc_v, acc_b, cnt, ovf), accept = jax.lax.scan(
        step, (acc_u, acc_v, acc_b, cnt, ovf), jnp.arange(m, dtype=jnp.int32)
    )
    return Phase1Result(accept=accept, group_overflow=ovf)


@functools.partial(jax.jit, static_argnames=("k_cap",))
def phase1_parallel(
    t: LiftingTables,
    su: jax.Array,
    sv: jax.Array,
    sbeta: jax.Array,
    layout: GroupLayout,
    k_cap: int = 32,
) -> Phase1Result:
    """Rank-lockstep greedy (parallel LGRASS): all groups advance together.

    Step r processes the r-th edge of every group as one vectorised lane
    batch — the TPU analogue of the paper's dynamic task dispatch. Total
    steps = max group size; each step is O(G * K * log N) dense work.
    """
    m = su.shape[0]
    garange = jnp.arange(m, dtype=jnp.int32)
    lane_live = garange < layout.n_groups
    # Trip count: longest *active* group only. Inactive slots (tree /
    # non-crossing / padding) all share the (UMAX, UMAX) tail group whose
    # lane never fires (`layout.active` is False there), so letting its
    # size — O(L) — drive the loop would only add no-op rounds.
    group_active = layout.active[jnp.minimum(layout.group_start, m - 1)]
    max_r = jnp.max(
        jnp.where(lane_live & group_active, layout.group_size, 0)
    )

    acc_u = jnp.zeros((m, k_cap), jnp.int32)
    acc_v = jnp.zeros((m, k_cap), jnp.int32)
    acc_b = jnp.full((m, k_cap), -1, jnp.int32)
    cnt = jnp.zeros((m,), jnp.int32)
    ovf = jnp.zeros((m,), bool)
    out = jnp.zeros((m,), bool)

    def cond(state):
        r = state[0]
        return r < max_r

    def body(state):
        r, acc_u, acc_v, acc_b, cnt, ovf, out = state
        gs = layout.group_start[garange]
        i = jnp.minimum(gs + r, m - 1)
        lane_act = lane_live & (r < layout.group_size[garange])
        lane_act = lane_act & layout.active[i]
        x = jnp.where(lane_act, su[i], 0)
        y = jnp.where(lane_act, sv[i], 0)
        cov = _ball_pair_covered(t, x, y, acc_u, acc_v, acc_b, cnt)
        accept = lane_act & ~cov
        full = cnt >= k_cap
        ovf = ovf | (accept & full)
        slot = jnp.minimum(cnt, k_cap - 1)
        store = accept & ~full
        acc_u = acc_u.at[garange, slot].set(jnp.where(store, x, acc_u[garange, slot]))
        acc_v = acc_v.at[garange, slot].set(jnp.where(store, y, acc_v[garange, slot]))
        acc_b = acc_b.at[garange, slot].set(
            jnp.where(store, sbeta[i], acc_b[garange, slot])
        )
        cnt = cnt + store.astype(jnp.int32)
        write_i = jnp.where(lane_act, i, m)  # dropped when inactive
        out = out.at[write_i].set(accept, mode="drop")
        return r + 1, acc_u, acc_v, acc_b, cnt, ovf, out

    _, acc_u, acc_v, acc_b, cnt, ovf, out = jax.lax.while_loop(
        cond, body, (jnp.int32(0), acc_u, acc_v, acc_b, cnt, ovf, out)
    )
    return Phase1Result(accept=out, group_overflow=ovf)
