"""Edge marking — LGRASS §3.1 + §4.2, the paper's core contribution.

The baseline marks edges with an O(N^2 L) triple loop (Alg. 1). LGRASS's
insight is twofold:

  1. *Node* marks instead of *edge* marks (Alg. 2/3): an accepted edge
     (u, v) with ball radius beta covers candidate (x, y) iff x and y lie
     in the paired balls B(u, beta) / B(v, beta).
  2. Crossing edges only interact within the same LCA (Lemma 3.1/3.2), so
     the greedy is partitioned into independent per-LCA subtasks, with
     root-LCA edges further split by their (subtree, subtree) pair — the
     paper's two-step mapping F(u, v) (§4.2).

TPU adaptation: instead of per-thread dynamic task queues we keep a
bounded table of accepted edges per group, (G, K) in HBM, and evaluate the
cover test *analytically* — dist(x, u_j) <= beta_j via batched LCA — which
replaces ball materialisation (pointer chasing) with dense gathers. Three
schedules are provided:

  * `phase1_chunked`  — the default: sorted slots are processed in
    blocks of C. Per block, ONE batched LCA call builds the cover table
    of all block candidates against (a) each slot's per-group accepted-
    buffer snapshot and (b) every other block slot; an arithmetic-only
    inner lax.scan then replays the block's accept/reject decisions with
    pure table lookups (no per-slot gathers), and the per-(L, K) tables
    are updated with one batched scatter per block. Crossing slots
    occupy a prefix of the sorted layout, so the outer while_loop runs
    ceil(n_crossing / C) blocks — the step count collapses from L to
    n_crossing / C (pdGRASS's density-aware batching, mapped from
    thread queues to lane blocks).
  * `phase1_basic`    — one lax.scan over edges in global criticality
    order (the paper's "basic LGRASS", Fig. 1b).
  * `phase1_parallel` — rank-lockstep over groups: at step r every group
    processes its r-th edge simultaneously (the paper's parallel edge
    marking, Fig. 2, mapped from thread-parallel to lane-parallel).

All three schedules are bit-identical (groups are independent and each
schedule preserves the within-group criticality order; tests/
test_marking_chunked.py sweeps them against the numpy oracle). The
`run_phase1` dispatcher selects one via `schedule="chunked" | "scan"`
(the latter picking basic or lockstep via `parallel`).

Groups whose accepted count exceeds K overflow; the host recovery stage
(recovery.py) re-checks those exactly, so K is a performance knob, never a
correctness knob.

Non-crossing edges are excluded here and replayed in recovery (Alg. 6),
exactly as the paper keeps that stage sequential (Fig. 1c).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lca import (
    EulerLCA,
    LiftingTables,
    kth_ancestor,
    lca,
    subroot,
    tree_distance,
    tree_distance_euler,
)
from repro.core.pow2 import auto_chunk
from repro.core.sort import (
    block_view,
    float32_sort_key,
    radix_argsort_u32,
    radix_argsort_u64pair,
    sort_f32_desc_stable,
)

UMAX = jnp.uint32(0xFFFFFFFF)


class GroupLayout(NamedTuple):
    perm: jax.Array         # (L,) int32 — edge ids sorted by (group, crit-rank)
    gidx: jax.Array         # (L,) int32 — dense group index per sorted slot
    group_start: jax.Array  # (L,) int32 — first sorted slot of each group
    group_size: jax.Array   # (L,) int32
    active: jax.Array       # (L,) bool  — sorted slot holds a crossing edge
    n_groups: jax.Array     # scalar int32 (incl. possibly one inactive tail)


@functools.partial(jax.jit, static_argnames=())
def group_keys(
    t: LiftingTables,
    root: jax.Array,
    u: jax.Array,
    v: jax.Array,
    edge_lca: jax.Array,
    is_offtree: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The paper's two-step partition key F(u, v) as a (hi, lo) uint32 pair.

    hi = 0, lo = lca                      if lca != root
    hi = s1 + 1, lo = s2                  if lca == root (crossing)
    (UMAX, UMAX)                          inactive (tree / non-crossing)

    where s1 >= s2 are the compact root-subtree indices of u, v. Using a
    key *pair* instead of N + 1 + C(s1, 2) + s2 avoids the paper's int
    overflow at large root degree while keeping the identical partition.
    """
    n = t.depth.shape[0]
    crossing = is_offtree & (edge_lca != u) & (edge_lca != v)
    is_child = t.depth == 1
    child_rank = jnp.cumsum(is_child.astype(jnp.int32)) - 1
    # ONE subroot climb over the n nodes, then two gathers per edge —
    # climbing (L,)-shaped endpoint arrays repeats every ancestor gather
    # ~2L/n times for nothing
    sub_all = subroot(t, jnp.arange(n, dtype=jnp.int32))
    s_u = child_rank[sub_all[u]]
    s_v = child_rank[sub_all[v]]
    s1 = jnp.maximum(s_u, s_v).astype(jnp.uint32)
    s2 = jnp.minimum(s_u, s_v).astype(jnp.uint32)
    at_root = edge_lca == root
    hi = jnp.where(at_root, s1 + 1, 0).astype(jnp.uint32)
    lo = jnp.where(at_root, s2, edge_lca.astype(jnp.uint32))
    hi = jnp.where(crossing, hi, UMAX)
    lo = jnp.where(crossing, lo, UMAX)
    return hi, lo, crossing


@jax.jit
def build_group_layout(
    crit: jax.Array,
    hi: jax.Array,
    lo: jax.Array,
    crossing: jax.Array,
    edge_valid: jax.Array | None = None,
) -> GroupLayout:
    """Sort edges by (group, criticality desc, id asc); derive group spans.

    edge_valid: optional (L,) padding mask (batched pipeline). Padding
    edges are forced out of every crossing group: they land in the
    inactive (UMAX, UMAX) tail group together with tree / non-crossing
    edges, where `active` is False, so phase 1 never inspects them and
    the dense group indices of real crossing groups are unchanged.

    Degenerate inputs are well-defined: with L == 0 (an isolated-node
    graph) every field is empty and n_groups == 0 — the static-shape
    branch below exists because `.at[0]` on an empty array raises even
    under jit. With zero crossing edges (star / chain topologies) the
    whole layout is the single inactive (UMAX, UMAX) tail group:
    `active` is all-False, so no schedule ever inspects a slot and no
    garbage reaches recovery (tests/test_marking_chunked.py pins both).
    """
    if edge_valid is not None:
        crossing = crossing & edge_valid
    m = crit.shape[0]
    if m == 0:
        zi = jnp.zeros((0,), jnp.int32)
        return GroupLayout(perm=zi, gidx=zi, group_start=zi, group_size=zi,
                           active=jnp.zeros((0,), bool),
                           n_groups=jnp.int32(0))
    p1 = sort_f32_desc_stable(jnp.where(crossing, crit, -jnp.inf))
    p2 = radix_argsort_u64pair(hi[p1], lo[p1])  # stable => keeps crit order
    perm = p1[p2]
    sh, sl = hi[perm], lo[perm]
    first = jnp.zeros((m,), dtype=bool).at[0].set(True)
    bnd = first | (sh != jnp.roll(sh, 1)) | (sl != jnp.roll(sl, 1))
    gidx = jnp.cumsum(bnd.astype(jnp.int32)) - 1
    group_start = jnp.full((m,), jnp.int32(m)).at[gidx].min(
        jnp.arange(m, dtype=jnp.int32)
    )
    group_size = jnp.zeros((m,), jnp.int32).at[gidx].add(1)
    active = crossing[perm]
    return GroupLayout(
        perm=perm,
        gidx=gidx,
        group_start=group_start,
        group_size=group_size,
        active=active,
        n_groups=gidx[-1] + 1,
    )


def ball_pair_table(
    t: LiftingTables,
    xs: jax.Array,
    ys: jax.Array,
    cols_u: jax.Array,
    cols_v: jax.Array,
    cols_b: jax.Array,
    use_tree_kernel: bool = False,
    euler: Optional[EulerLCA] = None,
) -> jax.Array:
    """Ball-pair cover table for a block of edges vs a set of candidates.

    xs, ys: (C,) block edge endpoints. cols_*: candidate accepted edges
    (u, v, beta) — either (K,) shared across the block (recovery's
    buffer snapshot ++ block endpoints) or (C, K) per-row (phase 1's
    per-group accepted-buffer gathers). Returns (C, K) bool — candidate
    j's ball pair covers block edge i:

        cover <=> (d(x,u_j) <= b_j and d(y,v_j) <= b_j) or swapped.

    The 4·C·K tree distances are ONE fused batched query — a binary-
    lifting climb by default, the Euler-tour O(1)-LCA sparse table when
    `euler` is given, or the Pallas tree-distance kernel under
    `use_tree_kernel`. This is where chunked schedules pay for their
    blocks: the climb's sequential latency is amortised over the whole
    (C, K) table instead of one edge's row.
    """
    c = xs.shape[0]
    k = cols_u.shape[-1]
    if cols_u.ndim == 1:
        cols_u = jnp.broadcast_to(cols_u[None, :], (c, k))
        cols_v = jnp.broadcast_to(cols_v[None, :], (c, k))
        cols_b = jnp.broadcast_to(cols_b[None, :], (c, k))
    qa = jnp.broadcast_to(jnp.stack([xs, ys, xs, ys])[:, :, None],
                          (4, c, k))
    qb = jnp.stack([cols_u, cols_v, cols_v, cols_u])
    if use_tree_kernel:
        from repro.kernels.ops import tree_dist_pairs

        d = tree_dist_pairs(t.up, t.depth, qa.ravel(),
                            jnp.broadcast_to(qb, (4, c, k)).ravel())
        d = d.reshape(4, c, k)
    elif euler is not None:
        d = tree_distance_euler(euler, qa, qb)
    else:
        d = tree_distance(t, qa, qb)
    return ((d[0] <= cols_b) & (d[1] <= cols_b)) | (
        (d[2] <= cols_b) & (d[3] <= cols_b)
    )


def _ball_pair_covered(
    t: LiftingTables,
    x: jax.Array,
    y: jax.Array,
    row_u: jax.Array,
    row_v: jax.Array,
    row_b: jax.Array,
    cnt: jax.Array,
) -> jax.Array:
    """Paired-ball cover test against a (…, K) accepted-edge table.

    covered <=> exists j < cnt:
        (d(x,u_j) <= b_j and d(y,v_j) <= b_j) or
        (d(x,v_j) <= b_j and d(y,u_j) <= b_j)

    Distances are tree hop distances via batched LCA — this is Alg. 3's
    check, evaluated analytically instead of via materialised ball sets.
    """
    k = row_u.shape[-1]
    xb = jnp.broadcast_to(x[..., None], row_u.shape)
    yb = jnp.broadcast_to(y[..., None], row_u.shape)

    def dist(a, b):
        w = lca(t, a, b)
        return t.depth[a] + t.depth[b] - 2 * t.depth[w]

    dxu = dist(xb, row_u)
    dxv = dist(xb, row_v)
    dyu = dist(yb, row_u)
    dyv = dist(yb, row_v)
    pair = ((dxu <= row_b) & (dyv <= row_b)) | ((dxv <= row_b) & (dyu <= row_b))
    valid = jnp.arange(k, dtype=jnp.int32) < cnt[..., None]
    return jnp.any(pair & valid, axis=-1)


class Phase1Result(NamedTuple):
    accept: jax.Array          # (L,) bool — per *sorted slot*
    group_overflow: jax.Array  # (L,) bool — per dense group index


def _empty_phase1() -> "Phase1Result":
    """The L == 0 result (isolated-node graphs; see build_group_layout)."""
    return Phase1Result(accept=jnp.zeros((0,), bool),
                        group_overflow=jnp.zeros((0,), bool))


@jax.jit
def phase1_edge_views(
    perm: jax.Array,
    gidx: jax.Array,
    accept_sorted: jax.Array,
    group_overflow: jax.Array,
    crossing: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Scatter phase-1's sorted-slot outputs back to edge-id order.

    The recovery stage consumes per-edge views: the phase-1 accept
    decision, the dense group index (-1 for anything that is not a
    crossing edge — tree, non-crossing, padding), and the initial dirty
    set (every crossing edge of an overflowed group). This is the glue
    between MARK and REC; the host tail computes the same three arrays
    in numpy (`_recovery_tail`), asserted equal by the parity tests.
    """
    L = perm.shape[0]
    accept_by_edge = jnp.zeros((L,), bool).at[perm].set(accept_sorted)
    group_of_edge = jnp.full((L,), -1, jnp.int32).at[perm].set(
        gidx.astype(jnp.int32)
    )
    group_of_edge = jnp.where(crossing, group_of_edge, -1)
    dirty0 = jnp.zeros((L,), bool).at[perm].set(
        group_overflow[gidx] & crossing[perm]
    )
    return accept_by_edge, group_of_edge, dirty0


@functools.partial(jax.jit, static_argnames=("k_cap",))
def phase1_basic(
    t: LiftingTables,
    su: jax.Array,
    sv: jax.Array,
    sbeta: jax.Array,
    layout: GroupLayout,
    k_cap: int = 32,
) -> Phase1Result:
    """Sequential greedy (basic LGRASS): one lax.scan over sorted slots."""
    m = su.shape[0]
    if m == 0:
        return _empty_phase1()
    acc_u = jnp.zeros((m, k_cap), jnp.int32)
    acc_v = jnp.zeros((m, k_cap), jnp.int32)
    acc_b = jnp.full((m, k_cap), -1, jnp.int32)
    cnt = jnp.zeros((m,), jnp.int32)
    ovf = jnp.zeros((m,), bool)

    def step(carry, i):
        acc_u, acc_v, acc_b, cnt, ovf = carry
        g = layout.gidx[i]
        act = layout.active[i]
        x = jnp.where(act, su[i], 0)
        y = jnp.where(act, sv[i], 0)
        cov = _ball_pair_covered(t, x, y, acc_u[g], acc_v[g], acc_b[g], cnt[g])
        accept = act & ~cov
        full = cnt[g] >= k_cap
        ovf = ovf.at[g].set(ovf[g] | (accept & full))
        slot = jnp.minimum(cnt[g], k_cap - 1)
        store = accept & ~full
        acc_u = acc_u.at[g, slot].set(jnp.where(store, x, acc_u[g, slot]))
        acc_v = acc_v.at[g, slot].set(jnp.where(store, y, acc_v[g, slot]))
        acc_b = acc_b.at[g, slot].set(
            jnp.where(store, sbeta[i], acc_b[g, slot])
        )
        cnt = cnt.at[g].add(store.astype(jnp.int32))
        return (acc_u, acc_v, acc_b, cnt, ovf), accept

    (acc_u, acc_v, acc_b, cnt, ovf), accept = jax.lax.scan(
        step, (acc_u, acc_v, acc_b, cnt, ovf), jnp.arange(m, dtype=jnp.int32)
    )
    return Phase1Result(accept=accept, group_overflow=ovf)


@functools.partial(jax.jit, static_argnames=("k_cap",))
def phase1_parallel(
    t: LiftingTables,
    su: jax.Array,
    sv: jax.Array,
    sbeta: jax.Array,
    layout: GroupLayout,
    k_cap: int = 32,
) -> Phase1Result:
    """Rank-lockstep greedy (parallel LGRASS): all groups advance together.

    Step r processes the r-th edge of every group as one vectorised lane
    batch — the TPU analogue of the paper's dynamic task dispatch. Total
    steps = max group size; each step is O(G * K * log N) dense work.
    """
    m = su.shape[0]
    if m == 0:
        return _empty_phase1()
    garange = jnp.arange(m, dtype=jnp.int32)
    lane_live = garange < layout.n_groups
    # Trip count: longest *active* group only. Inactive slots (tree /
    # non-crossing / padding) all share the (UMAX, UMAX) tail group whose
    # lane never fires (`layout.active` is False there), so letting its
    # size — O(L) — drive the loop would only add no-op rounds.
    group_active = layout.active[jnp.minimum(layout.group_start, m - 1)]
    max_r = jnp.max(
        jnp.where(lane_live & group_active, layout.group_size, 0)
    )

    acc_u = jnp.zeros((m, k_cap), jnp.int32)
    acc_v = jnp.zeros((m, k_cap), jnp.int32)
    acc_b = jnp.full((m, k_cap), -1, jnp.int32)
    cnt = jnp.zeros((m,), jnp.int32)
    ovf = jnp.zeros((m,), bool)
    out = jnp.zeros((m,), bool)

    def cond(state):
        r = state[0]
        return r < max_r

    def body(state):
        r, acc_u, acc_v, acc_b, cnt, ovf, out = state
        gs = layout.group_start[garange]
        i = jnp.minimum(gs + r, m - 1)
        lane_act = lane_live & (r < layout.group_size[garange])
        lane_act = lane_act & layout.active[i]
        x = jnp.where(lane_act, su[i], 0)
        y = jnp.where(lane_act, sv[i], 0)
        cov = _ball_pair_covered(t, x, y, acc_u, acc_v, acc_b, cnt)
        accept = lane_act & ~cov
        full = cnt >= k_cap
        ovf = ovf | (accept & full)
        slot = jnp.minimum(cnt, k_cap - 1)
        store = accept & ~full
        acc_u = acc_u.at[garange, slot].set(jnp.where(store, x, acc_u[garange, slot]))
        acc_v = acc_v.at[garange, slot].set(jnp.where(store, y, acc_v[garange, slot]))
        acc_b = acc_b.at[garange, slot].set(
            jnp.where(store, sbeta[i], acc_b[garange, slot])
        )
        cnt = cnt + store.astype(jnp.int32)
        write_i = jnp.where(lane_act, i, m)  # dropped when inactive
        out = out.at[write_i].set(accept, mode="drop")
        return r + 1, acc_u, acc_v, acc_b, cnt, ovf, out

    _, acc_u, acc_v, acc_b, cnt, ovf, out = jax.lax.while_loop(
        cond, body, (jnp.int32(0), acc_u, acc_v, acc_b, cnt, ovf, out)
    )
    return Phase1Result(accept=out, group_overflow=ovf)


@functools.partial(jax.jit,
                   static_argnames=("k_cap", "chunk", "use_tree_kernel"))
def phase1_chunked(
    t: LiftingTables,
    su: jax.Array,
    sv: jax.Array,
    sbeta: jax.Array,
    layout: GroupLayout,
    k_cap: int = 32,
    chunk: int = 32,
    use_tree_kernel: bool = False,
    euler: Optional[EulerLCA] = None,
) -> Phase1Result:
    """Two-level chunked greedy — the recovery-style replay for phase 1.

    Sorted slots are processed in blocks of `chunk`. Per block, ONE
    batched distance query answers every cover test the block can need:

      * block vs buffer — each slot i against the (k_cap,) accepted
        snapshot of *its own* group (slots only interact within a
        group), gathered as (C, K) per-row candidate tables;
      * block vs block — each slot i against every other block slot j,
        masked to same-group strictly-earlier accepted entries.

    The inner lax.scan then resolves the block's accept/reject chain
    with pure arithmetic on (C,)/(K,) vectors: coverage is a row lookup,
    the running per-group count is cnt-at-block-start plus a masked
    popcount of the stored-so-far vector, overflow is a compare. All
    table updates land in ONE batched scatter per block (distinct
    (group, slot) targets, rejects parked on row L and dropped).

    Crossing slots occupy a prefix of the sorted layout (non-crossing /
    tree / padding slots share the (UMAX, UMAX) tail group, which sorts
    last), so the outer while_loop runs ceil(n_crossing / chunk) blocks
    — never the full L. Decisions are integer comparisons throughout,
    hence bit-identical to `phase1_basic` / `phase1_parallel` / the
    numpy oracle (tests/test_marking_chunked.py).

    `euler`: optional Euler-tour O(1)-LCA tables (lca.py) backing the
    distance queries — O(1) gathers per query instead of O(log n).
    """
    m = su.shape[0]
    if m == 0:
        return _empty_phase1()
    c = max(min(chunk, m), 1)
    act_all = layout.active
    x_pad = block_view(jnp.where(act_all, su, 0).astype(jnp.int32), c, 0)
    y_pad = block_view(jnp.where(act_all, sv, 0).astype(jnp.int32), c, 0)
    b_pad = block_view(sbeta.astype(jnp.int32), c, -1)
    g_pad = block_view(layout.gidx, c, 0)
    act_pad = block_view(act_all, c, False)
    n_blocks = g_pad.shape[0]
    blocks_needed = (jnp.sum(act_all.astype(jnp.int32)) + c - 1) // c
    kiota = jnp.arange(k_cap, dtype=jnp.int32)
    ciota = jnp.arange(c, dtype=jnp.int32)

    def inner(store_vec, xs):
        cov_buf_i, pair_row, same_row, act_i, cnt0_i, i = xs
        hit = store_vec & same_row           # stored same-group, earlier
        cov = cov_buf_i | jnp.any(pair_row & hit)
        accept = act_i & ~cov
        cnt_here = cnt0_i + jnp.sum(hit.astype(jnp.int32))
        full = cnt_here >= k_cap
        store = accept & ~full
        store_vec = store_vec | ((ciota == i) & store)
        return store_vec, (accept, store, accept & full, cnt_here)

    def cond(state):
        return state[0] < blocks_needed

    def body(state):
        blk, acc_u, acc_v, acc_b, cnt, ovf, out = state
        pick = lambda a: jax.lax.dynamic_index_in_dim(a, blk,
                                                      keepdims=False)
        g, act = pick(g_pad), pick(act_pad)
        x, y, b = pick(x_pad), pick(y_pad), pick(b_pad)
        cnt0 = cnt[g]
        pair_buf = ball_pair_table(t, x, y, acc_u[g], acc_v[g], acc_b[g],
                                   use_tree_kernel, euler)
        cov_buf = jnp.any(pair_buf & (kiota[None, :] < cnt0[:, None]),
                          axis=1)
        pair_blk = ball_pair_table(t, x, y, x, y, b, use_tree_kernel,
                                   euler)
        same_prior = (g[:, None] == g[None, :]) & (
            ciota[None, :] < ciota[:, None]
        )
        _, (accept, store, oflag, cnt_at) = jax.lax.scan(
            inner, jnp.zeros((c,), bool),
            (cov_buf, pair_blk, same_prior, act, cnt0, ciota),
        )
        park = jnp.where(store, g, m)
        slot = jnp.minimum(cnt_at, k_cap - 1)
        acc_u = acc_u.at[park, slot].set(x, mode="drop")
        acc_v = acc_v.at[park, slot].set(y, mode="drop")
        acc_b = acc_b.at[park, slot].set(b, mode="drop")
        cnt = cnt.at[park].add(1, mode="drop")
        ovf = ovf.at[jnp.where(oflag, g, m)].set(True, mode="drop")
        out = jax.lax.dynamic_update_slice(out, accept, (blk * c,))
        return blk + 1, acc_u, acc_v, acc_b, cnt, ovf, out

    init = (
        jnp.int32(0),
        jnp.zeros((m, k_cap), jnp.int32),
        jnp.zeros((m, k_cap), jnp.int32),
        jnp.full((m, k_cap), -1, jnp.int32),  # -1 beta matches nothing
        jnp.zeros((m,), jnp.int32),
        jnp.zeros((m,), bool),
        jnp.zeros((n_blocks * c,), bool),
    )
    _, _, _, _, _, ovf, out = jax.lax.while_loop(cond, body, init)
    return Phase1Result(accept=out[:m], group_overflow=ovf)


def run_phase1(
    t: LiftingTables,
    su: jax.Array,
    sv: jax.Array,
    sbeta: jax.Array,
    layout: GroupLayout,
    k_cap: int = 32,
    schedule: str = "chunked",
    parallel: bool = True,
    chunk: Optional[int] = None,
    use_tree_kernel: bool = False,
    euler: Optional[EulerLCA] = None,
) -> Phase1Result:
    """Schedule dispatcher — the one entry every pipeline goes through.

    schedule="chunked" (default) runs `phase1_chunked` with an automatic
    pow2 block size (`core.pow2.auto_chunk`, ~sqrt(L)) unless `chunk`
    pins one; schedule="scan" keeps the legacy per-slot engines, with
    `parallel` picking rank-lockstep vs the basic sequential scan. All
    choices are bit-identical; this is purely a performance knob.
    """
    if schedule == "chunked":
        c = auto_chunk(int(su.shape[0])) if chunk is None else int(chunk)
        return phase1_chunked(t, su, sv, sbeta, layout, k_cap=k_cap,
                              chunk=c, use_tree_kernel=use_tree_kernel,
                              euler=euler)
    if schedule != "scan":
        raise ValueError(f"unknown phase-1 schedule {schedule!r}")
    fn = phase1_parallel if parallel else phase1_basic
    return fn(t, su, sv, sbeta, layout, k_cap=k_cap)
