"""Distributed LGRASS phase 1: groups sharded across the mesh (§4.2).

The paper dispatches per-LCA marking subtasks to threads with a greedy
dynamic scheduler. The multi-pod JAX equivalent:

  * host: `partition_groups` — greedy longest-processing-time bin packing
    of groups onto shards (the paper's greedy scheduler, done once up
    front since group sizes are known after the radix sort);
  * device: `phase1_sharded` — shard_map over ('pod', 'data'); every
    shard runs the rank-lockstep greedy on its own contiguous group block.
    Tree tables (lifting, depth) are replicated — they are O(N log N)
    int32, tiny next to the edge partition at scale. No collective is
    needed inside the loop because groups are provably independent
    (Lemma 3.1/3.2); one all-gather of accept flags at the end feeds the
    sequential recovery tail.

Fault-tolerance note: because shards are pure functions of (tables,
edge block), a failed worker's block can be re-dispatched to any survivor
— the trainer-level elastic machinery (repro.ft) reuses this property.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.lca import LiftingTables, lca
from repro.core.marking import _ball_pair_covered


def batch_mesh(n_devices: int | None = None, axis: str = "batch") -> Mesh:
    """A 1-axis mesh over the local devices for batch-axis sharding.

    `lgrass_device_batched` is embarrassingly parallel over its leading
    (graph) axis, so the serving plane shards that axis across this mesh
    (`SparsifyService(mesh=...)`). On CPU CI the multi-device path is
    exercised with XLA_FLAGS=--xla_force_host_platform_device_count=N
    (the bayespec/olmax trick from the related-repo snippets).
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if n > len(devs):
        raise ValueError(f"batch_mesh({n}) but only {len(devs)} devices")
    return compat.make_mesh((n,), (axis,))


def mesh_size(mesh: Mesh) -> int:
    """Total device count of `mesh` (the batch axis is sharded over ALL
    of its axes, so multi-axis meshes flatten into one factor)."""
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


def shard_batch_leading(arrays, mesh: Mesh):
    """device_put each array with its leading axis sharded across every
    axis of `mesh` (remaining dims replicated). The leading dim must be
    divisible by `mesh_size(mesh)` — the service pads the batch axis to
    guarantee that."""
    sh = NamedSharding(mesh, P(tuple(mesh.axis_names)))
    return tuple(jax.device_put(a, sh) for a in arrays)


@dataclasses.dataclass
class ShardedGroupPlan:
    """Host-side plan mapping sorted slots onto shards (padded, contiguous)."""

    slot_edge: np.ndarray     # (S * Lloc,) int64 — edge id per padded slot (-1 pad)
    group_start: np.ndarray   # (S * Lloc,) int32 — local starts per shard lane
    group_size: np.ndarray    # (S * Lloc,) int32
    n_shards: int
    local_len: int
    load: np.ndarray          # (S,) int64 — slots per shard (diagnostics)


def partition_groups(
    perm: np.ndarray,
    gidx: np.ndarray,
    active: np.ndarray,
    n_shards: int,
) -> ShardedGroupPlan:
    """Greedy LPT packing of whole groups onto shards.

    perm/gidx/active come from marking.build_group_layout (host copies).
    Groups never straddle shards, so shard-local greedy == global greedy
    per group (Lemma 3.1 independence).
    """
    m = len(perm)
    n_groups = int(gidx[-1]) + 1 if m else 0
    # group extents in sorted-slot space (active slots only)
    sizes = np.zeros(n_groups, np.int64)
    np.add.at(sizes, gidx[active], 1)
    starts = np.full(n_groups, m, np.int64)
    np.minimum.at(starts, gidx, np.arange(m))
    order = np.argsort(-sizes, kind="stable")  # LPT: big groups first
    load = np.zeros(n_shards, np.int64)
    assign = np.zeros(n_groups, np.int64)
    for gid in order:
        if sizes[gid] == 0:
            continue
        s = int(np.argmin(load))
        assign[gid] = s
        load[s] += sizes[gid]
    local_len = max(1, int(load.max()))
    slot_edge = np.full(n_shards * local_len, -1, np.int64)
    gstart = np.zeros(n_shards * local_len, np.int32)
    gsize = np.zeros(n_shards * local_len, np.int32)
    cursor = np.zeros(n_shards, np.int64)
    for gid in range(n_groups):
        size = int(sizes[gid])
        if size == 0:
            continue
        s = int(assign[gid])
        base = s * local_len + int(cursor[s])
        span = perm[starts[gid]: starts[gid] + size]
        slot_edge[base: base + size] = span
        gstart[base: base + size] = int(cursor[s])
        gsize[base: base + size] = size
        cursor[s] += size
    return ShardedGroupPlan(
        slot_edge=slot_edge,
        group_start=gstart,
        group_size=gsize,
        n_shards=n_shards,
        local_len=local_len,
        load=load,
    )


def _local_lockstep(up, depth, su, sv, sbeta, gstart, gsize, active, k_cap,
                    vary_axes=()):
    """Rank-lockstep greedy on one shard's block (no collectives)."""
    t = LiftingTables(up=up, depth=depth)
    m = su.shape[0]
    lanes = jnp.arange(m, dtype=jnp.int32)
    # lane g is live iff slot g begins a group (gstart == own local index)
    is_head = active & (gstart == lanes)
    max_r = jnp.max(jnp.where(is_head, gsize, 0))

    acc_u = jnp.zeros((m, k_cap), jnp.int32)
    acc_v = jnp.zeros((m, k_cap), jnp.int32)
    acc_b = jnp.full((m, k_cap), -1, jnp.int32)
    cnt = jnp.zeros((m,), jnp.int32)
    ovf = jnp.zeros((m,), bool)
    out = jnp.zeros((m,), bool)
    if vary_axes:
        # under shard_map the carries become device-varying on first write;
        # the initial values must carry the same varying type.
        acc_u, acc_v, acc_b, cnt, ovf, out = jax.tree.map(
            lambda a: compat.pvary(a, vary_axes),
            (acc_u, acc_v, acc_b, cnt, ovf, out),
        )

    def cond(state):
        return state[0] < max_r

    def body(state):
        r, acc_u, acc_v, acc_b, cnt, ovf, out = state
        i = jnp.minimum(lanes + r, m - 1)  # head lane g owns slots g..g+size-1
        lane_act = is_head & (r < gsize)
        lane_act = lane_act & active[i]
        x = jnp.where(lane_act, su[i], 0)
        y = jnp.where(lane_act, sv[i], 0)
        cov = _ball_pair_covered(t, x, y, acc_u, acc_v, acc_b, cnt)
        accept = lane_act & ~cov
        full = cnt >= k_cap
        ovf = ovf | (accept & full)
        slot = jnp.minimum(cnt, k_cap - 1)
        store = accept & ~full
        acc_u = acc_u.at[lanes, slot].set(jnp.where(store, x, acc_u[lanes, slot]))
        acc_v = acc_v.at[lanes, slot].set(jnp.where(store, y, acc_v[lanes, slot]))
        acc_b = acc_b.at[lanes, slot].set(
            jnp.where(store, sbeta[i], acc_b[lanes, slot])
        )
        cnt = cnt + store.astype(jnp.int32)
        write_i = jnp.where(lane_act, i, m)
        out = out.at[write_i].set(accept, mode="drop")
        return r + 1, acc_u, acc_v, acc_b, cnt, ovf, out

    _, _, _, _, _, ovf, out = jax.lax.while_loop(
        cond, body, (jnp.int32(0), acc_u, acc_v, acc_b, cnt, ovf, out)
    )
    return out, ovf


def make_phase1_sharded(mesh: Mesh, shard_axes: Tuple[str, ...], k_cap: int = 32):
    """Builds the shard_mapped phase-1 over `shard_axes` of `mesh`.

    Inputs (global shapes):
      up (LOG, n), depth (n,)              — replicated
      su/sv/sbeta/gstart/gsize/active (S*Lloc,) — sharded over shard_axes
    Output: accept flags + per-slot overflow, sharded the same way.

    NOTE on `gstart` semantics here: in the sharded plan, `group_start`
    is the *local* start index and each group-head lane is the slot where
    gstart equals its own local position (see partition_groups), which is
    what `_local_lockstep` expects.
    """
    spec_e = P(shard_axes)
    spec_r = P()

    def fn(up, depth, su, sv, sbeta, gstart, gsize, active):
        return _local_lockstep(
            up, depth, su, sv, sbeta, gstart, gsize, active, k_cap,
            vary_axes=shard_axes,
        )

    return jax.jit(
        compat.shard_map_unchecked(
            fn,
            mesh=mesh,
            in_specs=(spec_r, spec_r, spec_e, spec_e, spec_e, spec_e, spec_e,
                      spec_e),
            out_specs=(spec_e, spec_e),
        )
    )


def lgrass_phase1_distributed(g, mesh: Mesh, shard_axes=("data",),
                              k_cap: int = 32):
    """Host orchestration: device pipeline for tables -> plan -> sharded
    lockstep. Returns (accept_by_edge, overflow_dirty_by_edge, artifacts).
    """
    from repro.core.sparsify import phase1_device  # cycle-free local import

    n, L = g.n, g.m
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    w = jnp.asarray(g.w, jnp.float32)
    d = jax.device_get(phase1_device(u, v, w, n, k_cap, True))

    perm = d["perm"].astype(np.int64)
    gidx = d["gidx"].astype(np.int64)
    active = d["crossing"].astype(bool)[perm]
    n_shards = int(np.prod([mesh.shape[a] for a in shard_axes]))
    plan = partition_groups(perm, gidx, active, n_shards)

    eid = np.where(plan.slot_edge >= 0, plan.slot_edge, 0)
    su = jnp.asarray(g.u[eid], jnp.int32)
    sv = jnp.asarray(g.v[eid], jnp.int32)
    sbeta = jnp.asarray(d["beta"][eid], jnp.int32)
    act = jnp.asarray(plan.slot_edge >= 0)
    fn = make_phase1_sharded(mesh, tuple(shard_axes), k_cap)
    with compat.set_mesh(mesh):
        out, ovf = fn(
            jnp.asarray(d["up"]),
            jnp.asarray(d["depth_t"]),
            su, sv, sbeta,
            jnp.asarray(plan.group_start),
            jnp.asarray(plan.group_size),
            act,
        )
    out = np.asarray(jax.device_get(out))
    ovf = np.asarray(jax.device_get(ovf))
    accept_by_edge = np.zeros(L, bool)
    valid = plan.slot_edge >= 0
    accept_by_edge[plan.slot_edge[valid]] = out[valid]
    # overflow lane -> dirty every edge of that shard-local group
    dirty_by_edge = np.zeros(L, bool)
    if ovf.any():
        lanes = np.where(ovf)[0]
        for lane in lanes:
            shard = lane // plan.local_len
            lo = lane  # head lane owns slots lane..lane+size-1
            size = int(plan.group_size[lane])
            ids = plan.slot_edge[lo: lo + size]
            dirty_by_edge[ids[ids >= 0]] = True
    return accept_by_edge, dirty_by_edge, d
