"""The baseline program's semantics (IPCC reference, Algorithms 1 & 3).

This is the fidelity oracle: the original competition program marks edges
with the O(N^2 L) triple loop; we reproduce its *semantics* (greedy over
criticality-sorted off-tree edges, ball-pair edge marking, budget cut) at
O(L * ball) host cost — still super-linear, used only to validate that the
linear LGRASS pipeline produces the identical sparsifier.

Every float op mirrors the device pipeline bit-exactly (see _host.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core import _host as H
from repro.core.graph import Graph
from repro.core.mst import kruskal_mst_numpy


@dataclasses.dataclass
class BaselineResult:
    edge_mask: np.ndarray          # (L,) bool — final sparsifier edges
    accepted: np.ndarray           # accepted off-tree edge ids, accept order
    tree_mask: np.ndarray          # (L,) bool
    root: int
    depth_graph: np.ndarray
    depth_tree: np.ndarray
    parent_tree: np.ndarray
    eff: np.ndarray
    rank_eff: np.ndarray
    crit: np.ndarray
    beta: np.ndarray
    edge_lca: np.ndarray
    crossing: np.ndarray
    order: np.ndarray              # off-tree edges, (crit desc, id asc)
    marked: np.ndarray             # final mark state (diagnostics)


def default_budget(n: int) -> int:
    return max(1, int(round(0.05 * n)))


def baseline_sparsify(g: Graph, budget: int | None = None) -> BaselineResult:
    n, L = g.n, g.m
    u = g.u.astype(np.int64)
    v = g.v.astype(np.int64)
    w = g.w.astype(np.float32)
    if budget is None:
        budget = default_budget(n)

    # EFF: BFS depth on the full graph, depth-scaled effective weights
    root = H.select_root_np(u, v, n)
    depth_g, _ = H.bfs_np(u, v, n, root)
    eff = H.effective_weights_np(u, v, w, depth_g)

    # MST: maximum spanning tree under the (eff desc, id asc) total order
    order_eff = H.desc_stable_order_np(eff)
    rank_eff = H.rank_from_order(order_eff)
    tree_mask = kruskal_mst_numpy(u, v, rank_eff, n)

    # Tree BFS (depths/parents used for LCA, beta, balls)
    depth_t, parent_t = H.bfs_np(u, v, n, root, edge_mask=tree_mask)
    up = H.build_lifting_np(parent_t, depth_t, n)

    # RES: root-path resistance sums -> criticality
    inv_w = H.node_parent_inv_w_np(u, v, w, tree_mask, parent_t, n)
    rd = H.root_path_sums_np(up, depth_t, inv_w, n)
    edge_lca = H.lca_np(up, depth_t, u, v)
    crit = H.criticality_np(u, v, w, rd, edge_lca)
    beta = np.maximum(
        np.minimum(depth_t[u], depth_t[v]) - depth_t[edge_lca], 1
    ).astype(np.int32)
    crossing = (~tree_mask) & (edge_lca != u) & (edge_lca != v)

    # SORT: off-tree edges by (criticality desc, id asc)
    offtree = ~tree_mask
    keys = np.where(offtree, crit, np.float32(-np.inf)).astype(np.float32)
    order = H.desc_stable_order_np(keys)[: int(offtree.sum())]

    # MARK (Algorithm 1 semantics): greedy with ball-pair edge marking
    adj = H.tree_adjacency(parent_t, n)
    marked = np.zeros(L, bool)
    accepted: List[int] = []
    out = np.zeros(L, bool)
    for e in order:
        e = int(e)
        if marked[e]:
            continue
        out[e] = True
        accepted.append(e)
        if len(accepted) == budget:
            break
        s1 = H.ball_np(adj, int(u[e]), int(beta[e]))
        s2 = H.ball_np(adj, int(v[e]), int(beta[e]))
        m1 = np.zeros(n, bool)
        m2 = np.zeros(n, bool)
        m1[list(s1)] = True
        m2[list(s2)] = True
        cov = offtree & (
            (m1[u] & m2[v]) | (m2[u] & m1[v])
        )
        marked |= cov

    return BaselineResult(
        edge_mask=tree_mask | out,
        accepted=np.array(accepted, dtype=np.int64),
        tree_mask=tree_mask,
        root=root,
        depth_graph=depth_g,
        depth_tree=depth_t,
        parent_tree=parent_t,
        eff=eff,
        rank_eff=rank_eff,
        crit=crit,
        beta=beta,
        edge_lca=edge_lca,
        crossing=crossing,
        order=order,
        marked=marked,
    )
