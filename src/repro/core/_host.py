"""Bit-exact numpy mirrors of the device subroutines.

The python oracle (baseline.py) and the recovery tail (recovery.py) must
agree with the JAX pipeline down to float tie-breaks, so every float
computation here uses the *same expression and summation order* as the
device code (float32 throughout; XLA does not reassociate float adds, so
elementwise mirrors are bit-identical).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.pow2 import log2_ceil as _log2_ceil

INF_I32 = np.iinfo(np.int32).max


def bfs_np(u, v, n, root, edge_mask=None):
    """Mirror of bfs.bfs — smallest-id-parent, level synchronous."""
    src = np.concatenate([u, v])
    dst = np.concatenate([v, u])
    if edge_mask is not None:
        emask = np.concatenate([edge_mask, edge_mask])
    else:
        emask = np.ones_like(src, dtype=bool)
    depth = np.full(n, INF_I32, np.int32)
    parent = np.full(n, -1, np.int32)
    depth[root] = 0
    frontier = np.zeros(n, bool)
    frontier[root] = True
    level = 0
    while frontier.any():
        active = frontier[src] & emask
        cand = np.full(n, INF_I32, np.int64)
        np.minimum.at(cand, dst[active], src[active])
        newly = (cand != INF_I32) & (depth == INF_I32)
        parent[newly] = cand[newly]
        depth[newly] = level + 1
        frontier = newly
        level += 1
    return depth, parent


def select_root_np(u, v, n) -> int:
    deg = np.zeros(n, np.int64)
    np.add.at(deg, u, 1)
    np.add.at(deg, v, 1)
    return int(np.argmax(deg))


def effective_weights_np(u, v, w, depth) -> np.ndarray:
    # mirror of bfs.finite_depth: unreachable depths clamp to 0 so a
    # disconnected input cannot poison the weights with float32(2^31-1)
    d = np.where(depth == INF_I32, 0, depth).astype(np.float32)
    return (w.astype(np.float32) * (d[u] + d[v] + np.float32(1.0))).astype(
        np.float32
    )


def float32_sort_key_np(x: np.ndarray) -> np.ndarray:
    bits = x.astype(np.float32).view(np.uint32)
    sign = bits >> 31
    return np.where(sign == 1, ~bits, bits | np.uint32(0x80000000))


def desc_stable_order_np(keys_f32: np.ndarray) -> np.ndarray:
    """(key desc, index asc) order — mirrors sort.sort_f32_desc_stable."""
    k = float32_sort_key_np(keys_f32)
    return np.argsort(~k, kind="stable")


def rank_from_order(order: np.ndarray) -> np.ndarray:
    rank = np.empty(len(order), np.int32)
    rank[order] = np.arange(len(order), dtype=np.int32)
    return rank


def build_lifting_np(parent, depth, n):
    """Mirror of lca.build_lifting: up (LOG, n)."""
    log = _log2_ceil(n + 1)
    up = np.zeros((log, n), np.int32)
    up[0] = np.where(parent < 0, np.arange(n, dtype=np.int32), parent)
    for k in range(1, log):
        up[k] = up[k - 1][up[k - 1]]
    return up


def kth_ancestor_np(up, node, k):
    log = up.shape[0]
    cur = np.asarray(node).copy()
    k = np.asarray(k)
    for i in range(log):
        bit = (k >> i) & 1
        cur = np.where(bit == 1, up[i][cur], cur)
    return cur


def lca_np(up, depth, a, b):
    log = up.shape[0]
    a = np.asarray(a)
    b = np.asarray(b)
    da, db = depth[a], depth[b]
    a2 = kth_ancestor_np(up, a, np.maximum(da - db, 0))
    b2 = kth_ancestor_np(up, b, np.maximum(db - da, 0))
    for i in range(log):
        k = log - 1 - i
        ua, ub = up[k][a2], up[k][b2]
        jump = (a2 != b2) & (ua != ub)
        a2 = np.where(jump, ua, a2)
        b2 = np.where(jump, ub, b2)
    return np.where(a2 == b2, a2, up[0][a2])


def tree_dist_np(up, depth, a, b):
    w = lca_np(up, depth, a, b)
    return depth[a] + depth[b] - 2 * depth[w]


def node_parent_inv_w_np(u, v, w, tree_mask, parent, n):
    inv = np.zeros(n, np.float32)
    for arr_c, arr_p in ((u, v), (v, u)):
        is_child = tree_mask & (parent[arr_c] == arr_p)
        inv[arr_c[is_child]] = (np.float32(1.0) / w[is_child]).astype(np.float32)
    return inv


def root_path_sums_np(up, depth, inv_w, n):
    """Mirror of resistance.root_path_sums (same add order, float32)."""
    log = up.shape[0]
    ws = np.zeros((log, n), np.float32)
    ups = np.zeros((log, n), np.int32)
    cur_up = up[0].copy()
    cur_ws = inv_w.astype(np.float32).copy()
    for k in range(log):
        ups[k] = cur_up
        ws[k] = cur_ws
        cur_ws = (cur_ws + cur_ws[cur_up]).astype(np.float32)
        cur_up = cur_up[cur_up]
    nodes = np.arange(n, dtype=np.int32)
    rd = np.zeros(n, np.float32)
    cur = nodes.copy()
    rem = depth.astype(np.int32).copy()
    for i in range(log):
        k = log - 1 - i
        take = ((rem >> k) & 1) == 1
        rd = (rd + np.where(take, ws[k][cur], np.float32(0.0))).astype(np.float32)
        cur = np.where(take, ups[k][cur], cur)
        rem = rem & ~(1 << k)
    return rd


def criticality_np(u, v, w, rd, edge_lca) -> np.ndarray:
    r = (rd[u] + rd[v] - np.float32(2.0) * rd[edge_lca]).astype(np.float32)
    return (w.astype(np.float32) * r).astype(np.float32)


def tree_children(parent, n):
    kids = [[] for _ in range(n)]
    for c in range(n):
        p = parent[c]
        if p >= 0:
            kids[p].append(c)
    return kids


def ball_np(adj, center: int, beta: int) -> set:
    """Nodes within tree hop distance <= beta of center (adj = tree lists)."""
    seen = {center}
    frontier = [center]
    for _ in range(beta):
        nxt = []
        for x in frontier:
            for y in adj[x]:
                if y not in seen:
                    seen.add(y)
                    nxt.append(y)
        frontier = nxt
        if not frontier:
            break
    return seen


def tree_adjacency(parent, n):
    adj = [[] for _ in range(n)]
    for c in range(n):
        p = parent[c]
        if p >= 0:
            adj[c].append(p)
            adj[p].append(c)
    return adj


def phase1_np(up, depth_t, su, sv, sbeta, gidx, active, k_cap):
    """Numpy oracle for phase-1 marking — mirrors every device schedule.

    Inputs are the *sorted-slot* views (marking.GroupLayout order):
    su/sv/sbeta the edge endpoints and ball radii per sorted slot, gidx
    the dense group index, `active` the crossing-slot mask. Replays the
    per-group greedy sequentially: accept a slot iff no *stored* earlier
    same-group accept covers its ball pair (tree distances via the
    binary-lifting tables); store at most k_cap accepts per group; an
    accept past k_cap only raises the group's overflow flag, exactly as
    `phase1_basic`/`phase1_parallel`/`phase1_chunked` do on device.

    Returns (accept (L,) bool per sorted slot, overflow (L,) bool per
    dense group index) — the `Phase1Result` layout.
    """
    m = len(su)
    accept = np.zeros(m, bool)
    overflow = np.zeros(m, bool)
    stored: dict = {}
    for i in range(m):
        if not active[i]:
            continue
        g = int(gidx[i])
        lst = stored.setdefault(g, [])
        x, y, b = int(su[i]), int(sv[i]), int(sbeta[i])
        covered = False
        for (au, av, ab) in lst:
            dxu = int(tree_dist_np(up, depth_t, x, au))
            dxv = int(tree_dist_np(up, depth_t, x, av))
            dyu = int(tree_dist_np(up, depth_t, y, au))
            dyv = int(tree_dist_np(up, depth_t, y, av))
            if (dxu <= ab and dyv <= ab) or (dxv <= ab and dyu <= ab):
                covered = True
                break
        if covered:
            continue
        accept[i] = True
        if len(lst) >= k_cap:
            overflow[g] = True
        else:
            lst.append((x, y, b))
    return accept, overflow
