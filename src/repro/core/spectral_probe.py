"""Solver-free effective-resistance estimation (after SF-GRASS,
arXiv:2008.07633) — spectral quality at sizes the dense oracle cannot
reach.

The quality tier's ground truth is the dense Laplacian pseudoinverse
(`resistance.dense_effective_resistance_np`): O(n³), dead around 10⁴
nodes. This module estimates the same quantities with nothing but spmv,
fully device-resident and jit/vmap-able:

    R(a, b) = ‖W^{1/2} B L⁺ (e_a − e_b)‖²        (Spielman–Srivastava)

Sketch the edge dimension with P Rademacher probes ξ_p ∈ {±1}^m, lift
them to nodes (y_p = Bᵀ W^{1/2} ξ_p — one scatter-add), and run k
rounds of weighted-Jacobi or Chebyshev iteration on L x_p = y_p (one
spmv per round). Then

    R̂(a, b) = (1/P) Σ_p (x_p[a] − x_p[b])²,     E_ξ[R̂] → R as k → ∞.

Both iterations are polynomial filters p_k(λ) ≈ 1/λ on the
degree-normalised spectrum [0, 2]. The residual 1 − λ·p_k(λ) stays in
[0, 1] for every λ ≥ 0 — for ω ≤ 1 Jacobi trivially, for Chebyshev
because the residual is T_k((θ−λ)/δ)/T_k(θ/δ), which is 1 at λ = 0 and
bounded by 1 in magnitude on [0, 2θ] — so the estimator can truncate
smooth modes but never amplify anything: finite on ANY input, including
disconnected forests (each component's probe load is balanced; null
modes only shift per-component constants, which cancel in endpoint
differences). Two error terms, two knobs:

  * truncation — p_k saturates below a cutoff: Chebyshev resolves 1/λ
    down to λ ≳ lam_min (auto 8/k², the point where k sweeps of the
    accelerated recurrence stop converging), Jacobi down to λ ≳ 1/(ωk).
    Truncation only ever *underestimates* R (p_k(λ) ≤ 1/λ).
  * variance — the Hutchinson sketch carries relative noise ~ sqrt(2/P)
    per edge. Rank fidelity of the criticality ordering is the
    contract: tests/test_spectral_probe.py calibrates against the dense
    pinv at small n (Spearman ≥ 0.95) and records the probe/error
    tradeoff; benchmarks/bench_spectral.py records quality-vs-budget.

Because tr(L_G⁺ L_H) = Σ_{e ∈ H} w_e R_G(u_e, v_e), the per-edge
estimates double as a sparsifier quality score (`trace_similarity`):
bounded by n − #components with equality at H = G, and — estimates
being truncated from below — a lower bound in expectation: preservation
the score reports is preservation the sparsifier actually has.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# spectrum of D^{-1} L lives in [0, 2]; the filters are built for it
LAM_MAX = 2.0


def auto_lam_min(n_iters: int) -> float:
    """Smallest eigenvalue k Chebyshev rounds can resolve: the interval
    [α, 2] with k·sqrt(2α) ≈ 4 keeps T_k(θ/δ) ≈ cosh(4), i.e. the
    residual uniformly ≲ 0.07 on [α, 2] — tighter α would leave the
    low end unconverged, looser wastes resolution."""
    return min(0.5, 8.0 / float(max(n_iters, 1)) ** 2)


def weighted_degree(u: jax.Array, v: jax.Array, w: jax.Array, n: int,
                    edge_valid: Optional[jax.Array] = None) -> jax.Array:
    """(n,) float32 weighted degrees (padding edges contribute 0)."""
    wm = w if edge_valid is None else jnp.where(edge_valid, w, 0.0)
    wm = wm.astype(jnp.float32)
    deg = jnp.zeros((n,), jnp.float32)
    return deg.at[u].add(wm).at[v].add(wm)


def laplacian_spmv(u: jax.Array, v: jax.Array, w: jax.Array,
                   x: jax.Array, *,
                   edge_valid: Optional[jax.Array] = None,
                   use_spmv_kernel: bool = False) -> jax.Array:
    """y = L x for x: (n, P) — one gather + two scatter-adds (default),
    or the Pallas one-hot kernel (`kernels/spmv.py`) when selected.
    Padding edges are zero-weight self loops either way, so no mask
    arithmetic survives into the inner loop."""
    wm = w if edge_valid is None else jnp.where(edge_valid, w, 0.0)
    wm = wm.astype(jnp.float32)
    if use_spmv_kernel:
        from repro.kernels.ops import laplacian_spmv_edges

        return laplacian_spmv_edges(u, v, wm, x)
    d = x[u] - x[v]
    c = wm[:, None] * d
    return jnp.zeros_like(x).at[u].add(c).at[v].add(-c)


def _solve_jacobi(spmv, dinv, y, n_iters: int, omega) -> jax.Array:
    """x ← x + ω D⁻¹ (y − L x), x₀ = 0: residual filter (1 − ωλ̃)^k."""
    om = jnp.float32(omega)

    def step(_, x):
        return x + om * dinv[:, None] * (y - spmv(x))

    return jax.lax.fori_loop(0, n_iters, step, jnp.zeros_like(y))


def _solve_cheby(spmv, dinv, y, n_iters: int, lam_min) -> jax.Array:
    """Chebyshev iteration on D⁻¹L x = D⁻¹y over [lam_min, LAM_MAX]
    (Saad, Alg. 12.1). Scalars ride the carry as float32 so the x64 CI
    leg cannot silently promote the recurrence."""
    lam_min = jnp.float32(lam_min)
    theta = jnp.float32(0.5) * (jnp.float32(LAM_MAX) + lam_min)
    delta = jnp.float32(0.5) * (jnp.float32(LAM_MAX) - lam_min)
    sigma1 = theta / delta
    c = dinv[:, None] * y

    def m_apply(x):
        return dinv[:, None] * spmv(x)

    def step(_, state):
        x, r, d, rho = state
        x = x + d
        r = r - m_apply(d)
        rho_new = jnp.float32(1.0) / (jnp.float32(2.0) * sigma1 - rho)
        d = rho_new * rho * d + (jnp.float32(2.0) * rho_new / delta) * r
        return x, r, d, rho_new

    state = (jnp.zeros_like(c), c, c / theta, jnp.float32(1.0) / sigma1)
    x, _, _, _ = jax.lax.fori_loop(0, n_iters, step, state)
    return x


@functools.partial(
    jax.jit,
    static_argnames=("n", "n_probes", "n_iters", "method",
                     "use_spmv_kernel"))
def _probe_er_program(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    edge_valid: Optional[jax.Array],
    qu: jax.Array,
    qv: jax.Array,
    key: jax.Array,
    omega: jax.Array,
    lam_min: jax.Array,
    n: int,
    n_probes: int,
    n_iters: int,
    method: str,
    use_spmv_kernel: bool,
) -> jax.Array:
    """The device program: probes → lift → k spmv rounds → R̂ gathers."""
    m = u.shape[0]
    wm = w if edge_valid is None else jnp.where(edge_valid, w, 0.0)
    wm = wm.astype(jnp.float32)

    xi = jax.random.rademacher(key, (m, n_probes), jnp.float32)
    sw = jnp.sqrt(wm)[:, None] * xi                    # W^{1/2} ξ
    y = (jnp.zeros((n, n_probes), jnp.float32)
         .at[u].add(sw).at[v].add(-sw))                # Bᵀ W^{1/2} ξ

    deg = weighted_degree(u, v, wm, n)
    dinv = jnp.where(deg > 0.0, 1.0 / deg, 0.0).astype(jnp.float32)

    def spmv(x):
        return laplacian_spmv(u, v, wm, x,
                              use_spmv_kernel=use_spmv_kernel)

    if method == "jacobi":
        x = _solve_jacobi(spmv, dinv, y, n_iters, omega)
    elif method == "cheby":
        x = _solve_cheby(spmv, dinv, y, n_iters, lam_min)
    else:
        raise ValueError(f"unknown probe method {method!r}")

    d = x[qu] - x[qv]                                  # (Lq, P)
    return jnp.sum(d * d, axis=1, dtype=jnp.float32) / jnp.float32(
        n_probes)


def probe_edge_resistance(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    n: int,
    qu: Optional[jax.Array] = None,
    qv: Optional[jax.Array] = None,
    *,
    n_probes: int = 64,
    n_iters: int = 64,
    method: str = "cheby",
    omega: float = 2.0 / 3.0,
    lam_min: Optional[float] = None,
    seed: int = 0,
    key: Optional[jax.Array] = None,
    edge_valid: Optional[jax.Array] = None,
    use_spmv_kernel: bool = False,
) -> jax.Array:
    """Solver-free approximate effective resistances R̂(qu_i, qv_i).

    Queries default to the graph's own edge list — the shape the
    quality tiers need (per-edge R̂ feeds both the criticality ordering
    and the trace-similarity score). `method` picks the filter:
    "cheby" (default — sharper 1/λ resolution per spmv) or "jacobi"
    (the plainest smoother; `omega` is its damping). `lam_min` bounds
    the Chebyshev interval from below (None → `auto_lam_min(n_iters)`).
    With `edge_valid`, padding slots carry zero weight everywhere —
    they never touch degrees, probes' lift, or the spmv — and R̂ is
    returned for every query slot, padded queries included (node 0
    against itself → 0.0). Padding does reshape the Rademacher draw
    ((L_pad, P) vs (L, P)), so a padded run is a different
    same-distribution sketch than an unpadded one, with the same
    calibration contract.

    Endpoints in the same component get calibrated estimates
    (tests/test_spectral_probe.py). Cross-component queries — where the
    true R is infinite — return finite filter-saturated values:
    bounded garbage by design, pinned in the degenerate tests.
    """
    if qu is None:
        qu = u
    if qv is None:
        qv = v
    if key is None:
        key = jax.random.PRNGKey(seed)
    if lam_min is None:
        lam_min = auto_lam_min(n_iters)
    return _probe_er_program(
        jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
        jnp.asarray(w, jnp.float32),
        None if edge_valid is None else jnp.asarray(edge_valid, bool),
        jnp.asarray(qu, jnp.int32), jnp.asarray(qv, jnp.int32),
        key, jnp.float32(omega), jnp.float32(lam_min),
        n=int(n), n_probes=int(n_probes), n_iters=int(n_iters),
        method=method, use_spmv_kernel=bool(use_spmv_kernel))


@functools.partial(
    jax.jit,
    static_argnames=("n", "n_probes", "n_iters", "method",
                     "use_spmv_kernel"))
def _probe_er_batched_program(u, v, w, edge_valid, keys, omega, lam_min,
                              n, n_probes, n_iters, method,
                              use_spmv_kernel):
    return jax.vmap(
        lambda bu, bv, bw, bev, bk: _probe_er_program(
            bu, bv, bw, bev, bu, bv, bk, omega, lam_min, n=n,
            n_probes=n_probes, n_iters=n_iters, method=method,
            use_spmv_kernel=use_spmv_kernel)
    )(u, v, w, edge_valid, keys)


def probe_edge_resistance_batched(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    edge_valid: jax.Array,
    n: int,
    *,
    n_probes: int = 64,
    n_iters: int = 64,
    method: str = "cheby",
    omega: float = 2.0 / 3.0,
    lam_min: Optional[float] = None,
    seed: int = 0,
) -> jax.Array:
    """`probe_edge_resistance` vmapped over a padded `GraphBatch`:
    (B, L_max) edge arrays in, (B, L_max) per-edge R̂ out, one dispatch.
    Each lane draws its own probe key: lane i is bit-identical to a
    single-graph `probe_edge_resistance` call on the same padded arrays
    with seed `seed + i` (asserted in tests/test_spectral_probe.py).
    Against an UNpadded run the estimates differ only through the probe
    sample — the Rademacher draw is shaped (L_max, P), so padding
    changes which same-distribution sketch is drawn, not its quality;
    the calibration contract holds for both."""
    if lam_min is None:
        lam_min = auto_lam_min(n_iters)
    b = u.shape[0]
    keys = jax.vmap(lambda s: jax.random.PRNGKey(s))(
        jnp.arange(seed, seed + b, dtype=jnp.uint32))
    return _probe_er_batched_program(
        jnp.asarray(u, jnp.int32), jnp.asarray(v, jnp.int32),
        jnp.asarray(w, jnp.float32), jnp.asarray(edge_valid, bool),
        keys, jnp.float32(omega), jnp.float32(lam_min),
        n=int(n), n_probes=int(n_probes), n_iters=int(n_iters),
        method=method, use_spmv_kernel=False)


def probe_criticality(w: jax.Array, r_hat: jax.Array) -> jax.Array:
    """Solver-free criticality proxy w(e) · R̂(u, v) — the estimator's
    stand-in for `resistance.criticality`'s w(e) · R_T(u, v) sort key,
    with the *graph* (not tree) resistance under the hood."""
    return w.astype(jnp.float32) * r_hat


def trace_similarity(w: jax.Array, r_hat: jax.Array,
                     mask: Optional[jax.Array] = None) -> jax.Array:
    """Approximate tr(L_G⁺ L_H) = Σ_{e ∈ H} w_e R_G(u_e, v_e), with H
    the `mask`-selected subgraph and R̂ estimated once on G for every
    edge. Scalar in [0, n − #components]; equality at H = G; larger is
    spectrally closer. The truncated filter underestimates each term,
    so in expectation this is a LOWER bound on the true trace."""
    terms = w.astype(jnp.float32) * r_hat
    if mask is not None:
        terms = jnp.where(mask, terms, 0.0)
    return jnp.sum(terms, dtype=jnp.float32)
