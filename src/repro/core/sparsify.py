"""LGRASS end-to-end pipeline (Fig. 1b/1c): the public sparsifier API.

    EFF  -> graph BFS + depth-scaled effective weights      (bfs.py)
    MST  -> Borůvka maximum spanning tree                   (mst.py)
    LCA  -> binary lifting + root-subtree shortcut          (lca.py)
    RES  -> root-path resistance sums -> criticality        (resistance.py)
    SORT -> 4-pass radix sort on IEEE-754 keys              (sort.py)
    MARK -> per-group greedy, basic or lockstep-parallel    (marking.py)
    REC  -> greedy replay of non-crossing edges             (recovery.py)

All stages are jit-compiled device programs. `lgrass_device` fuses the
whole pipeline — phase 1 *and* the Algorithm-6 recovery replay — into a
single dispatch, and `lgrass_device_batched` vmaps it over a padded
graph batch, so the serving path never syncs to host between phases.
The host recovery tail (`recovery.recover_host`) is retained as the
fidelity oracle behind `recovery="host"`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import _host as H
from repro.core.baseline import default_budget
from repro.core.bfs import (
    bfs,
    effective_weights,
    finite_depth,
    root_tree_euler,
    select_root,
)
from repro.core.graph import Graph
from repro.core.lca import (
    LiftingTables,
    build_euler,
    build_lifting,
    lca_euler,
    lca_with_shortcut,
)
from repro.core.marking import (
    GroupLayout,
    Phase1Result,
    build_group_layout,
    group_keys,
    phase1_basic,
    phase1_edge_views,
    phase1_parallel,
    run_phase1,
)
from repro.core.mst import boruvka_mst
from repro.core.pow2 import log2_ceil, next_pow2
from repro.core.recovery import _recover_scan, recover_host
from repro.core.resistance import (
    criticality,
    node_parent_inv_w,
    root_path_sums,
)
from repro.core.sort import sort_f32_desc_stable

# Device recovery holds accepted edges in a (b_cap,) buffer; b_cap is a
# compiled constant, so small budgets share one bucketed program.
B_CAP_FLOOR = 8


def _bucket_b_cap(budgets) -> int:
    """Static accept-buffer size covering every budget in `budgets`."""
    need = max([int(b) for b in budgets] + [1])
    return max(next_pow2(need), B_CAP_FLOOR)


@dataclasses.dataclass
class SparsifyResult:
    edge_mask: np.ndarray       # (L,) bool — tree + accepted off-tree edges
    tree_mask: np.ndarray       # (L,) bool
    accepted_mask: np.ndarray   # (L,) bool — accepted off-tree edges
    n_accepted: int
    n_groups: int
    n_overflow_groups: int
    n_dirty: int


def _phase1_program(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    n: int,
    k_cap: int,
    parallel: bool,
    lift_levels: int | None,
    edge_valid: jax.Array | None,
    schedule: str = "chunked",
    p1_chunk: int | None = None,
    use_euler_lca: bool = True,
    use_tree_kernel: bool = False,
    bfs_engine: str = "doubling",
):
    """EFF→MST→LCA→RES→SORT→MARK(phase 1), optionally padding-masked.

    With edge_valid=None this is exactly the single-graph device program.
    With a padding mask (batched pipeline, see `GraphBatch`) every stage
    is threaded so padding edges can never enter the tree or a crossing
    group, and all real-slot outputs are bit-identical to an unpadded run
    of the same graph (binary-lifting depth only grows with n, and extra
    levels are provable no-ops for both LCA climbs and root-path sums).

    schedule/p1_chunk select the MARK engine (marking.run_phase1):
    "chunked" (default, block size p1_chunk or auto-pow2 ~sqrt(L)) or
    "scan" (the legacy engines; `parallel` picks lockstep vs basic). All
    schedules are bit-identical. use_euler_lca additionally builds the
    Euler-tour O(1)-LCA tables once and backs the chunked cover tables
    with them; use_tree_kernel routes those tables through the Pallas
    tree-distance kernel instead.

    bfs_engine picks the two traversal passes' implementation
    (bfs.py): "doubling" (default) runs the graph pass as the
    O(log n)-round hop-doubling engine and replaces the tree pass with
    the Euler-tour rooting (`root_tree_euler` — no BFS at all);
    "levels" keeps both passes level-synchronous. On the pipeline's
    legal inputs (connected graphs, graph.py's contract) outputs are
    bit-identical and this is purely a performance knob
    (tests/test_bfs_doubling.py; diameter-bound feeder chains are
    where "doubling" wins). The BFS engines themselves agree on ANY
    input including disconnected forests, but downstream LCA values
    for *unreachable* endpoints are backend-dependent garbage under
    every backend, so full-pipeline parity is only promised where the
    pipeline is defined.
    """
    root = select_root(u, v, n, edge_valid)
    depth_g, _ = bfs(u, v, n, root, edge_mask=edge_valid,
                     engine=bfs_engine)
    eff = effective_weights(u, v, w, depth_g, n, edge_valid)

    perm_eff = sort_f32_desc_stable(eff, valid=edge_valid)
    rank_eff = (
        jnp.zeros_like(perm_eff)
        .at[perm_eff]
        .set(jnp.arange(perm_eff.shape[0], dtype=jnp.int32))
    )
    tree_mask = boruvka_mst(u, v, rank_eff, n, edge_valid)

    # the Pallas kernel path takes precedence inside ball_pair_table, so
    # skip the (then-unused) Euler build when it is selected. Built for
    # ANY schedule: the fused recovery replay consumes it too.
    want_euler = use_euler_lca and not use_tree_kernel
    euler = None
    if bfs_engine == "doubling":
        # exact O(log n) tree rooting via the Euler tour — the tree's
        # depth/parent are unique, so no fixpoint iteration is needed;
        # the rooted tour doubles as the O(1)-LCA tables (no second
        # tour construction via build_euler)
        depth_t, parent_t, euler = root_tree_euler(
            u, v, n, root, tree_mask, with_euler=want_euler)
    else:
        depth_t, parent_t = bfs(u, v, n, root, edge_mask=tree_mask,
                                engine=bfs_engine)
        if want_euler:
            euler = build_euler(parent_t, depth_t, root, n)
    t = build_lifting(parent_t, depth_t, n, levels=lift_levels)
    if euler is not None:
        # O(1) gathers per edge instead of L-wide lifting climbs; the
        # LCA of two reachable nodes is backend-independent, so every
        # downstream value is bit-identical
        elca = lca_euler(euler, u, v)
    else:
        elca = lca_with_shortcut(t, root, u, v)
    inv_w = node_parent_inv_w(u, v, w, tree_mask, parent_t, n)
    r = root_path_sums(t, inv_w)
    crit = criticality(t, r, u, v, w, elca)
    beta = jnp.maximum(
        jnp.minimum(depth_t[u], depth_t[v]) - depth_t[elca], 1
    ).astype(jnp.int32)

    is_offtree = ~tree_mask if edge_valid is None else (~tree_mask) & edge_valid
    hi, lo, crossing = group_keys(t, root, u, v, elca, is_offtree)
    layout = build_group_layout(crit, hi, lo, crossing, edge_valid)
    su, sv, sbeta = u[layout.perm], v[layout.perm], beta[layout.perm]
    p1 = run_phase1(t, su, sv, sbeta, layout, k_cap=k_cap,
                    schedule=schedule, parallel=parallel, chunk=p1_chunk,
                    use_tree_kernel=use_tree_kernel,
                    euler=euler if schedule == "chunked" else None)
    d = dict(
        tree_mask=tree_mask,
        parent_t=parent_t,
        depth_t=depth_t,
        up=t.up,
        beta=beta,
        crit=crit,
        crossing=crossing,
        perm=layout.perm,
        gidx=layout.gidx,
        accept_sorted=p1.accept,
        group_overflow=p1.group_overflow,
        n_groups=layout.n_groups,
    )
    return d, euler


@functools.partial(jax.jit,
                   static_argnames=("n", "k_cap", "parallel", "lift_levels",
                                    "schedule", "p1_chunk", "use_euler_lca",
                                    "use_tree_kernel", "bfs_engine"))
def phase1_device(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    n: int,
    k_cap: int = 32,
    parallel: bool = True,
    lift_levels: int | None = None,
    schedule: str = "chunked",
    p1_chunk: int | None = None,
    use_euler_lca: bool = True,
    use_tree_kernel: bool = False,
    bfs_engine: str = "doubling",
):
    """The phase-1 device program: EFF→MST→LCA→RES→SORT→MARK.

    Returns everything the host recovery tail needs. This function is the
    unit the multi-pod dry-run lowers and compiles.
    """
    d, _ = _phase1_program(u, v, w, n, k_cap, parallel, lift_levels, None,
                           schedule, p1_chunk, use_euler_lca,
                           use_tree_kernel, bfs_engine)
    return d


@functools.partial(jax.jit,
                   static_argnames=("n", "k_cap", "parallel", "lift_levels",
                                    "schedule", "p1_chunk", "use_euler_lca",
                                    "use_tree_kernel", "bfs_engine"))
def phase1_device_batched(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    edge_valid: jax.Array,
    n: int,
    k_cap: int = 32,
    parallel: bool = True,
    lift_levels: int | None = None,
    schedule: str = "chunked",
    p1_chunk: int | None = None,
    use_euler_lca: bool = True,
    use_tree_kernel: bool = False,
    bfs_engine: str = "doubling",
):
    """`phase1_device` vmapped over a leading batch axis.

    Args are (B, L_max) padded edge lists plus the (B, L_max) padding
    mask; `n` is the shared node pad n_max. One compile + one dispatch
    covers the whole batch — the amortisation the serving path needs.
    """
    return jax.vmap(
        lambda bu, bv, bw, bev: _phase1_program(
            bu, bv, bw, n, k_cap, parallel, lift_levels, bev,
            schedule, p1_chunk, use_euler_lca, use_tree_kernel,
            bfs_engine
        )[0]
    )(u, v, w, edge_valid)


def _lgrass_program(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    budget: jax.Array,
    n: int,
    k_cap: int,
    parallel: bool,
    lift_levels: int | None,
    b_cap: int,
    edge_valid: jax.Array | None,
    use_tree_kernel: bool,
    chunk: int = 32,
    schedule: str = "chunked",
    p1_chunk: int | None = None,
    use_euler_lca: bool = True,
    bfs_engine: str = "doubling",
):
    """Phase 1 + device recovery fused into one program (Fig. 1b end-to-end).

    The MARK outputs are scattered back to edge-id order on device
    (`phase1_edge_views`), the global criticality order is taken over all
    off-tree edges, and the Algorithm-6 replay runs as a lax.scan — no
    host round-trip anywhere. Only scalars and the final masks leave the
    device.
    """
    d, euler = _phase1_program(u, v, w, n, k_cap, parallel, lift_levels,
                               edge_valid, schedule, p1_chunk,
                               use_euler_lca, use_tree_kernel, bfs_engine)
    t = LiftingTables(up=d["up"], depth=d["depth_t"])
    tree_mask = d["tree_mask"]
    crossing = d["crossing"]
    accept_by_edge, group_of_edge, dirty0 = phase1_edge_views(
        d["perm"], d["gidx"], d["accept_sorted"], d["group_overflow"],
        crossing,
    )
    offtree = ~tree_mask if edge_valid is None else (~tree_mask) & edge_valid
    keys = jnp.where(offtree, d["crit"], -jnp.inf)
    order = sort_f32_desc_stable(keys)
    accepted, n_accepted = _recover_scan(
        t, u, v, d["beta"], offtree, crossing, order, accept_by_edge,
        group_of_edge, dirty0, jnp.asarray(budget, jnp.int32), b_cap,
        use_tree_kernel, chunk, euler,
    )
    depth_fin = finite_depth(d["depth_t"])
    return dict(
        tree_mask=tree_mask,
        accepted=accepted,
        n_accepted=n_accepted,
        n_groups=d["n_groups"],
        n_overflow_groups=jnp.sum(d["group_overflow"].astype(jnp.int32)),
        n_dirty=jnp.sum(dirty0.astype(jnp.int32)),
        tree_depth_max=jnp.max(depth_fin),
    )


@functools.partial(jax.jit,
                   static_argnames=("n", "k_cap", "parallel", "lift_levels",
                                    "b_cap", "use_tree_kernel", "chunk",
                                    "schedule", "p1_chunk", "use_euler_lca",
                                    "bfs_engine"))
def lgrass_device(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    budget: jax.Array,
    n: int,
    k_cap: int = 32,
    parallel: bool = True,
    lift_levels: int | None = None,
    b_cap: int = B_CAP_FLOOR,
    use_tree_kernel: bool = False,
    chunk: int = 32,
    schedule: str = "chunked",
    p1_chunk: int | None = None,
    use_euler_lca: bool = True,
    bfs_engine: str = "doubling",
):
    """The full device program: phase 1 fused with the recovery replay.

    `budget` is a traced int32 scalar (one compile serves any budget up
    to the static buffer bound `b_cap`). Returns final masks + scalar
    stats only — the first point data leaves the device.
    """
    return _lgrass_program(u, v, w, budget, n, k_cap, parallel,
                           lift_levels, b_cap, None, use_tree_kernel, chunk,
                           schedule, p1_chunk, use_euler_lca, bfs_engine)


def _lgrass_batched_impl(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    edge_valid: jax.Array,
    budget: jax.Array,
    n: int,
    k_cap: int = 32,
    parallel: bool = True,
    lift_levels: int | None = None,
    b_cap: int = B_CAP_FLOOR,
    use_tree_kernel: bool = False,
    chunk: int = 32,
    schedule: str = "chunked",
    p1_chunk: int | None = None,
    use_euler_lca: bool = True,
    bfs_engine: str = "doubling",
):
    return jax.vmap(
        lambda bu, bv, bw, bev, bb: _lgrass_program(
            bu, bv, bw, bb, n, k_cap, parallel, lift_levels, b_cap, bev,
            use_tree_kernel, chunk, schedule, p1_chunk, use_euler_lca,
            bfs_engine,
        )
    )(u, v, w, edge_valid, budget)


_BATCHED_STATICS = ("n", "k_cap", "parallel", "lift_levels", "b_cap",
                    "use_tree_kernel", "chunk", "schedule", "p1_chunk",
                    "use_euler_lca", "bfs_engine")

lgrass_device_batched = jax.jit(
    _lgrass_batched_impl, static_argnames=_BATCHED_STATICS)
lgrass_device_batched.__doc__ = (
    """`lgrass_device` vmapped over a padded batch: ONE dispatch runs
    phase 1 *and* recovery for every graph — no host round-trip between
    phases. `budget` is a (B,) int32 vector (per-graph budgets)."""
)

# The serving plane's steady-state variant: the padded edge arrays and
# the budget vector are donated, so XLA reuses their device buffers for
# the outputs instead of allocating fresh ones every request. Callers
# must hand over arrays they will never touch again (the service builds
# them fresh from its host staging pool each chunk; see
# serve/sparsify_service.py). Same program, bit-identical outputs —
# donation only changes buffer lifetime.
lgrass_device_batched_donated = jax.jit(
    _lgrass_batched_impl, static_argnames=_BATCHED_STATICS,
    donate_argnums=(0, 1, 2, 3, 4))


def _result_from_device(d: dict, i: Optional[int], L: int) -> SparsifyResult:
    """Slice one graph's `SparsifyResult` out of (batched) device outputs."""
    pick = (lambda x: x[i]) if i is not None else (lambda x: x)
    tree_mask = np.asarray(pick(d["tree_mask"])).astype(bool)[:L]
    accepted = np.asarray(pick(d["accepted"])).astype(bool)[:L]
    return SparsifyResult(
        edge_mask=tree_mask | accepted,
        tree_mask=tree_mask,
        accepted_mask=accepted,
        n_accepted=int(pick(d["n_accepted"])),
        n_groups=int(pick(d["n_groups"])),
        n_overflow_groups=int(pick(d["n_overflow_groups"])),
        n_dirty=int(pick(d["n_dirty"])),
    )


def lgrass_sparsify(
    g: Graph,
    budget: Optional[int] = None,
    k_cap: int = 32,
    parallel: bool = True,
    auto_lift_bound: bool = False,
    recovery: str = "device",
    b_cap: Optional[int] = None,
    use_tree_kernel: bool = False,
    chunk: int = 32,
    schedule: str = "chunked",
    p1_chunk: Optional[int] = None,
    use_euler_lca: bool = True,
    bfs_engine: str = "doubling",
) -> SparsifyResult:
    """Run LGRASS on a host graph; returns the sparsifier edge mask.

    recovery: "device" (default) runs the fused `lgrass_device` program —
    one dispatch end-to-end; "host" runs phase 1 on device and replays
    Algorithm 6 with the numpy oracle (`recover_host`). Both are
    bit-identical (tests/test_recovery_device.py).

    schedule/p1_chunk: the phase-1 marking engine — "chunked" (default;
    block size p1_chunk, or an auto pow2 ~sqrt(L)) or "scan" (legacy
    per-slot engines, `parallel` picking lockstep vs basic). All
    schedules are bit-identical (tests/test_marking_chunked.py);
    use_euler_lca (default on) backs the chunked cover tables with the
    Euler-tour O(1) LCA built once per graph — measured faster than the
    lifting climbs at every size on CPU, including the build.

    bfs_engine: the traversal engine for both BFS passes — "doubling"
    (default: hop-doubling graph BFS + Euler-tour tree rooting,
    O(log n) rounds on chain-like inputs) or "levels" (the legacy
    level-synchronous passes). Bit-identical outputs
    (tests/test_bfs_doubling.py); benchmarks/bench_bfs.py measures the
    difference on the diameter-bound feeder family.

    auto_lift_bound: measure the tree depth first (one extra BFS) and
    build depth-bounded lifting tables — identical output, ~log(N)/log(D)
    less LCA gather traffic (§Perf 'lift_bound').

    b_cap: static accept-buffer bound for device recovery; defaults to a
    pow2 bucket of `budget` so nearby budgets share compiled programs.
    """
    n, L = g.n, g.m
    if budget is None:
        budget = default_budget(n)
    budget = int(budget)
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    w = jnp.asarray(g.w, jnp.float32)

    lift_levels = None
    if auto_lift_bound:
        # estimate from graph BFS depth ×4 (tree paths stretch); the
        # post-hoc check below guarantees correctness regardless.
        root = select_root(u, v, n)
        depth_g, _ = bfs(u, v, n, root, engine=bfs_engine)
        # finite_depth: unreachable (INF) depths must not inflate the
        # estimate — the shared bfs.py guard, not an ad-hoc mask
        dmax = int(jax.device_get(jnp.max(finite_depth(depth_g))))
        safe = 1
        while (1 << safe) <= 4 * max(dmax, 1):
            safe += 1
        lift_levels = min(safe, log2_ceil(n + 1))

    if recovery == "device":
        if b_cap is None:
            b_cap = _bucket_b_cap([budget])
        if b_cap < budget:
            raise ValueError(f"b_cap {b_cap} < budget {budget}")
        d = jax.device_get(lgrass_device(
            u, v, w, jnp.int32(budget), n, k_cap, parallel, lift_levels,
            b_cap, use_tree_kernel, chunk, schedule, p1_chunk,
            use_euler_lca, bfs_engine))
        if lift_levels is not None:
            if int(d["tree_depth_max"]) >= (1 << lift_levels):
                d = jax.device_get(lgrass_device(
                    u, v, w, jnp.int32(budget), n, k_cap, parallel, None,
                    b_cap, use_tree_kernel, chunk, schedule, p1_chunk,
                    use_euler_lca, bfs_engine))
        return _result_from_device(d, None, L)
    if recovery != "host":
        raise ValueError(f"unknown recovery mode {recovery!r}")

    d = jax.device_get(phase1_device(u, v, w, n, k_cap, parallel,
                                     lift_levels, schedule, p1_chunk,
                                     use_euler_lca, use_tree_kernel,
                                     bfs_engine))
    if lift_levels is not None:
        tree_dmax = int(d["depth_t"].max())
        if tree_dmax >= (1 << lift_levels):  # bound violated: redo safely
            d = jax.device_get(phase1_device(u, v, w, n, k_cap, parallel,
                                             None, schedule, p1_chunk,
                                             use_euler_lca,
                                             use_tree_kernel, bfs_engine))
    return _recovery_tail(g, d, budget)


def phase1_views_np(d: dict, L: int):
    """Numpy mirror of `marking.phase1_edge_views` + the global
    criticality order — the glue between MARK and a host-side replay.

    `d` holds one graph's phase-1 outputs as numpy arrays of padded
    length L_pad >= L (slicing to the leading L real slots is exact:
    padding edges were kept out of the tree and every crossing group on
    device, see graph.py's padding conventions). Returns (tree_mask,
    crossing, accept_by_edge, group_of_edge, dirty0, order) with `order`
    the full (L,) (crit desc, id asc) permutation, off-tree edges first.

    Shared by `_recovery_tail`, bench_recovery.py and the recovery parity
    tests so there is exactly ONE host formulation to drift-check against
    the device glue.
    """
    L_pad = int(d["tree_mask"].shape[0])
    crossing_p = d["crossing"].astype(bool)
    perm = d["perm"].astype(np.int64)
    gidx = d["gidx"].astype(np.int64)

    # per-edge phase-1 decision / dense group / overflow dirtiness
    accept_by_edge = np.zeros(L_pad, bool)
    accept_by_edge[perm] = d["accept_sorted"]
    group_of_edge = np.full(L_pad, -1, np.int64)
    group_of_edge[perm] = gidx
    group_of_edge[~crossing_p] = -1
    dirty0 = np.zeros(L_pad, bool)
    dirty0[perm] = d["group_overflow"].astype(bool)[gidx] & crossing_p[perm]

    tree_mask = d["tree_mask"].astype(bool)[:L]
    # global criticality order over all off-tree edges (incl. non-crossing)
    keys = np.where(~tree_mask, d["crit"][:L],
                    np.float32(-np.inf)).astype(np.float32)
    order = H.desc_stable_order_np(keys)
    return (tree_mask, crossing_p[:L], accept_by_edge[:L],
            group_of_edge[:L], dirty0[:L], order)


def _recovery_tail(g: Graph, d: dict, budget: int) -> SparsifyResult:
    """Host recovery from one graph's phase-1 outputs (the oracle tail)."""
    n, L = g.n, g.m
    (tree_mask, crossing, accept_by_edge, group_of_edge, dirty0,
     order) = phase1_views_np(d, L)
    ovf_groups = d["group_overflow"].astype(bool)
    crit_order = order[: int((~tree_mask).sum())]

    accepted = recover_host(
        n=n,
        u=g.u.astype(np.int64),
        v=g.v.astype(np.int64),
        tree_mask=tree_mask,
        parent_t=d["parent_t"][:n],
        depth_t=d["depth_t"][:n],
        up=d["up"][:, :n],
        beta=d["beta"][:L],
        crossing=crossing,
        crit_order=crit_order,
        phase1_accept=accept_by_edge,
        group_of_edge=group_of_edge,
        dirty0=dirty0,
        budget=budget,
    )
    return SparsifyResult(
        edge_mask=tree_mask | accepted,
        tree_mask=tree_mask,
        accepted_mask=accepted,
        n_accepted=int(accepted.sum()),
        n_groups=int(d["n_groups"]),
        n_overflow_groups=int(ovf_groups.sum()),
        n_dirty=int(dirty0.sum()),
    )


def lgrass_sparsify_batch(
    graphs,
    budget: Optional[int] = None,
    k_cap: int = 32,
    parallel: bool = True,
    recovery: str = "device",
    b_cap: Optional[int] = None,
    use_tree_kernel: bool = False,
    chunk: int = 32,
    schedule: str = "chunked",
    p1_chunk: Optional[int] = None,
    use_euler_lca: bool = True,
    bfs_engine: str = "doubling",
) -> list:
    """Run LGRASS on many graphs with ONE device compile + dispatch.

    graphs: a `GraphBatch`, or a sequence of `Graph`s (padded here).
    budget: None -> per-graph `default_budget(g.n)`; a scalar applies to
    every graph; a sequence gives one budget per graph (None entries
    fall back to that graph's default).

    recovery="device" (default) runs `lgrass_device_batched`: phase 1
    AND the Algorithm-6 replay execute in the one vmapped dispatch, with
    per-graph budgets as a traced vector — only final masks and scalar
    stats come back to host. recovery="host" keeps the oracle path:
    batched phase 1, then a per-graph numpy replay. Results are
    bit-identical either way, and to per-graph `lgrass_sparsify(g)`
    (asserted in tests/test_batch.py and tests/test_recovery_device.py).
    """
    from repro.core.graph import GraphBatch

    batch = (graphs if isinstance(graphs, GraphBatch)
             else GraphBatch.from_graphs(list(graphs)))
    if budget is None or np.ndim(budget) == 0:
        budget = [budget] * len(batch.graphs)
    elif len(budget) != len(batch.graphs):
        raise ValueError("one budget per graph required")
    budgets = [default_budget(g.n) if b is None else int(b)
               for g, b in zip(batch.graphs, budget)]

    if recovery == "device":
        if b_cap is None:
            b_cap = _bucket_b_cap(budgets)
        if b_cap < max(budgets):
            raise ValueError(f"b_cap {b_cap} < max budget {max(budgets)}")
        d = jax.device_get(lgrass_device_batched(
            jnp.asarray(batch.u, jnp.int32),
            jnp.asarray(batch.v, jnp.int32),
            jnp.asarray(batch.w, jnp.float32),
            jnp.asarray(batch.edge_valid, bool),
            jnp.asarray(np.asarray(budgets, np.int32)),
            batch.n_max,
            k_cap,
            parallel,
            None,
            b_cap,
            use_tree_kernel,
            chunk,
            schedule,
            p1_chunk,
            use_euler_lca,
            bfs_engine,
        ))
        return [_result_from_device(d, i, g.m)
                for i, g in enumerate(batch.graphs)]
    if recovery != "host":
        raise ValueError(f"unknown recovery mode {recovery!r}")

    d = jax.device_get(phase1_device_batched(
        jnp.asarray(batch.u, jnp.int32),
        jnp.asarray(batch.v, jnp.int32),
        jnp.asarray(batch.w, jnp.float32),
        jnp.asarray(batch.edge_valid, bool),
        batch.n_max,
        k_cap,
        parallel,
        None,
        schedule,
        p1_chunk,
        use_euler_lca,
        use_tree_kernel,
        bfs_engine,
    ))
    results = []
    for i, (g, b) in enumerate(zip(batch.graphs, budgets)):
        di = {k: np.asarray(val[i]) for k, val in d.items()}
        results.append(_recovery_tail(g, di, b))
    return results
