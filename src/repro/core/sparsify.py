"""LGRASS end-to-end pipeline (Fig. 1b/1c): the public sparsifier API.

    EFF  -> graph BFS + depth-scaled effective weights      (bfs.py)
    MST  -> Borůvka maximum spanning tree                   (mst.py)
    LCA  -> binary lifting + root-subtree shortcut          (lca.py)
    RES  -> root-path resistance sums -> criticality        (resistance.py)
    SORT -> 4-pass radix sort on IEEE-754 keys              (sort.py)
    MARK -> per-group greedy, basic or lockstep-parallel    (marking.py)
    REC  -> sequential recovery of non-crossing edges       (recovery.py)

All device stages are jit-compiled; `phase1_device` additionally exposes
the full device program as a single jittable function for the multi-pod
dry-run. The recovery tail runs on host, mirroring the paper's own
sequential Algorithm 6 stage.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import _host as H
from repro.core.baseline import default_budget
from repro.core.bfs import bfs, effective_weights, select_root
from repro.core.graph import Graph
from repro.core.lca import build_lifting, lca_with_shortcut
from repro.core.marking import (
    GroupLayout,
    Phase1Result,
    build_group_layout,
    group_keys,
    phase1_basic,
    phase1_parallel,
)
from repro.core.mst import boruvka_mst
from repro.core.recovery import recover
from repro.core.resistance import (
    criticality,
    node_parent_inv_w,
    root_path_sums,
)
from repro.core.sort import sort_f32_desc_stable


def _log2_ceil_host(n: int) -> int:
    k = 1
    while (1 << k) < n:
        k += 1
    return max(k, 1)


@dataclasses.dataclass
class SparsifyResult:
    edge_mask: np.ndarray       # (L,) bool — tree + accepted off-tree edges
    tree_mask: np.ndarray       # (L,) bool
    accepted_mask: np.ndarray   # (L,) bool — accepted off-tree edges
    n_accepted: int
    n_groups: int
    n_overflow_groups: int
    n_dirty: int


def _phase1_program(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    n: int,
    k_cap: int,
    parallel: bool,
    lift_levels: int | None,
    edge_valid: jax.Array | None,
):
    """EFF→MST→LCA→RES→SORT→MARK(phase 1), optionally padding-masked.

    With edge_valid=None this is exactly the single-graph device program.
    With a padding mask (batched pipeline, see `GraphBatch`) every stage
    is threaded so padding edges can never enter the tree or a crossing
    group, and all real-slot outputs are bit-identical to an unpadded run
    of the same graph (binary-lifting depth only grows with n, and extra
    levels are provable no-ops for both LCA climbs and root-path sums).
    """
    root = select_root(u, v, n, edge_valid)
    depth_g, _ = bfs(u, v, n, root, edge_mask=edge_valid)
    eff = effective_weights(u, v, w, depth_g, n)

    perm_eff = sort_f32_desc_stable(eff, valid=edge_valid)
    rank_eff = (
        jnp.zeros_like(perm_eff)
        .at[perm_eff]
        .set(jnp.arange(perm_eff.shape[0], dtype=jnp.int32))
    )
    tree_mask = boruvka_mst(u, v, rank_eff, n, edge_valid)

    depth_t, parent_t = bfs(u, v, n, root, edge_mask=tree_mask)
    t = build_lifting(parent_t, depth_t, n, levels=lift_levels)
    elca = lca_with_shortcut(t, root, u, v)
    inv_w = node_parent_inv_w(u, v, w, tree_mask, parent_t, n)
    r = root_path_sums(t, inv_w)
    crit = criticality(t, r, u, v, w, elca)
    beta = jnp.maximum(
        jnp.minimum(depth_t[u], depth_t[v]) - depth_t[elca], 1
    ).astype(jnp.int32)

    is_offtree = ~tree_mask if edge_valid is None else (~tree_mask) & edge_valid
    hi, lo, crossing = group_keys(t, root, u, v, elca, is_offtree)
    layout = build_group_layout(crit, hi, lo, crossing, edge_valid)
    su, sv, sbeta = u[layout.perm], v[layout.perm], beta[layout.perm]
    fn = phase1_parallel if parallel else phase1_basic
    p1 = fn(t, su, sv, sbeta, layout, k_cap=k_cap)
    return dict(
        tree_mask=tree_mask,
        parent_t=parent_t,
        depth_t=depth_t,
        up=t.up,
        beta=beta,
        crit=crit,
        crossing=crossing,
        perm=layout.perm,
        gidx=layout.gidx,
        accept_sorted=p1.accept,
        group_overflow=p1.group_overflow,
        n_groups=layout.n_groups,
    )


@functools.partial(jax.jit,
                   static_argnames=("n", "k_cap", "parallel", "lift_levels"))
def phase1_device(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    n: int,
    k_cap: int = 32,
    parallel: bool = True,
    lift_levels: int | None = None,
):
    """The full device program: EFF→MST→LCA→RES→SORT→MARK(phase 1).

    Returns everything the host recovery tail needs. This function is the
    unit the multi-pod dry-run lowers and compiles.
    """
    return _phase1_program(u, v, w, n, k_cap, parallel, lift_levels, None)


@functools.partial(jax.jit,
                   static_argnames=("n", "k_cap", "parallel", "lift_levels"))
def phase1_device_batched(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    edge_valid: jax.Array,
    n: int,
    k_cap: int = 32,
    parallel: bool = True,
    lift_levels: int | None = None,
):
    """`phase1_device` vmapped over a leading batch axis.

    Args are (B, L_max) padded edge lists plus the (B, L_max) padding
    mask; `n` is the shared node pad n_max. One compile + one dispatch
    covers the whole batch — the amortisation the serving path needs.
    """
    return jax.vmap(
        lambda bu, bv, bw, bev: _phase1_program(
            bu, bv, bw, n, k_cap, parallel, lift_levels, bev
        )
    )(u, v, w, edge_valid)


def lgrass_sparsify(
    g: Graph,
    budget: Optional[int] = None,
    k_cap: int = 32,
    parallel: bool = True,
    auto_lift_bound: bool = False,
) -> SparsifyResult:
    """Run LGRASS on a host graph; returns the sparsifier edge mask.

    auto_lift_bound: measure the tree depth first (one extra BFS) and
    build depth-bounded lifting tables — identical output, ~log(N)/log(D)
    less LCA gather traffic (§Perf 'lift_bound').
    """
    n, L = g.n, g.m
    if budget is None:
        budget = default_budget(n)
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    w = jnp.asarray(g.w, jnp.float32)

    lift_levels = None
    if auto_lift_bound:
        # estimate from graph BFS depth ×4 (tree paths stretch); the
        # post-hoc check below guarantees correctness regardless.
        root = select_root(u, v, n)
        depth_g, _ = bfs(u, v, n, root)
        dmax = int(jax.device_get(jnp.max(jnp.where(
            depth_g == jnp.iinfo(jnp.int32).max, 0, depth_g))))
        safe = 1
        while (1 << safe) <= 4 * max(dmax, 1):
            safe += 1
        lift_levels = min(safe, _log2_ceil_host(n + 1))

    d = jax.device_get(phase1_device(u, v, w, n, k_cap, parallel,
                                     lift_levels))
    if lift_levels is not None:
        tree_dmax = int(d["depth_t"].max())
        if tree_dmax >= (1 << lift_levels):  # bound violated: redo safely
            d = jax.device_get(phase1_device(u, v, w, n, k_cap, parallel,
                                             None))
    return _recovery_tail(g, d, budget)


def _recovery_tail(g: Graph, d: dict, budget: int) -> SparsifyResult:
    """Host recovery from one graph's phase-1 outputs.

    `d` holds numpy arrays of padded length L_pad >= g.m (node tables of
    n_pad >= g.n); the single-graph path passes L_pad == L. Padding slots
    are sliced away after the per-edge scatters: padding edges were kept
    out of the tree and every crossing group on device, so real slots
    carry exactly the unpadded values.
    """
    n, L = g.n, g.m
    L_pad = int(d["tree_mask"].shape[0])
    tree_mask_p = d["tree_mask"].astype(bool)
    crossing_p = d["crossing"].astype(bool)
    perm = d["perm"].astype(np.int64)
    gidx = d["gidx"].astype(np.int64)

    # per-edge phase-1 decision / dense group / overflow dirtiness
    accept_by_edge = np.zeros(L_pad, bool)
    accept_by_edge[perm] = d["accept_sorted"]
    group_of_edge = np.full(L_pad, -1, np.int64)
    group_of_edge[perm] = gidx
    group_of_edge[~crossing_p] = -1
    ovf_groups = d["group_overflow"].astype(bool)
    dirty0 = np.zeros(L_pad, bool)
    cross_perm_mask = crossing_p[perm]
    dirty_sorted = ovf_groups[gidx] & cross_perm_mask
    dirty0[perm] = dirty_sorted

    tree_mask = tree_mask_p[:L]
    crossing = crossing_p[:L]
    accept_by_edge = accept_by_edge[:L]
    group_of_edge = group_of_edge[:L]
    dirty0 = dirty0[:L]

    # global criticality order over all off-tree edges (incl. non-crossing)
    offtree = ~tree_mask
    keys = np.where(offtree, d["crit"][:L],
                    np.float32(-np.inf)).astype(np.float32)
    crit_order = H.desc_stable_order_np(keys)[: int(offtree.sum())]

    accepted = recover(
        n=n,
        u=g.u.astype(np.int64),
        v=g.v.astype(np.int64),
        tree_mask=tree_mask,
        parent_t=d["parent_t"][:n],
        depth_t=d["depth_t"][:n],
        up=d["up"][:, :n],
        beta=d["beta"][:L],
        crossing=crossing,
        crit_order=crit_order,
        phase1_accept=accept_by_edge,
        group_of_edge=group_of_edge,
        dirty0=dirty0,
        budget=budget,
    )
    return SparsifyResult(
        edge_mask=tree_mask | accepted,
        tree_mask=tree_mask,
        accepted_mask=accepted,
        n_accepted=int(accepted.sum()),
        n_groups=int(d["n_groups"]),
        n_overflow_groups=int(ovf_groups.sum()),
        n_dirty=int(dirty0.sum()),
    )


def lgrass_sparsify_batch(
    graphs,
    budget: Optional[int] = None,
    k_cap: int = 32,
    parallel: bool = True,
) -> list:
    """Run LGRASS on many graphs with ONE device compile + dispatch.

    graphs: a `GraphBatch`, or a sequence of `Graph`s (padded here).
    budget: None -> per-graph `default_budget(g.n)`; a scalar applies to
    every graph; a sequence gives one budget per graph (None entries
    fall back to that graph's default).

    Phase 1 runs as `phase1_device_batched` over the padded (B, L_max)
    edge lists; the recovery tail then replays each graph on host exactly
    as the single-graph path does. Results are bit-identical to calling
    `lgrass_sparsify(g)` per graph (asserted in tests/test_batch.py).
    """
    from repro.core.graph import GraphBatch

    batch = (graphs if isinstance(graphs, GraphBatch)
             else GraphBatch.from_graphs(list(graphs)))
    if budget is None or np.ndim(budget) == 0:
        budget = [budget] * len(batch.graphs)
    elif len(budget) != len(batch.graphs):
        raise ValueError("one budget per graph required")
    budgets = [default_budget(g.n) if b is None else int(b)
               for g, b in zip(batch.graphs, budget)]

    d = jax.device_get(phase1_device_batched(
        jnp.asarray(batch.u, jnp.int32),
        jnp.asarray(batch.v, jnp.int32),
        jnp.asarray(batch.w, jnp.float32),
        jnp.asarray(batch.edge_valid, bool),
        batch.n_max,
        k_cap,
        parallel,
        None,
    ))
    results = []
    for i, (g, b) in enumerate(zip(batch.graphs, budgets)):
        di = {k: np.asarray(val[i]) for k, val in d.items()}
        results.append(_recovery_tail(g, di, b))
    return results
