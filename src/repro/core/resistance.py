"""Tree effective resistance in O(L) (LGRASS §3.2, after feGRASS).

For a spanning tree T, the effective resistance between u and v is the sum
of 1/w along the unique tree path:

    R_T(u, v) = rd[u] + rd[v] - 2 * rd[lca(u, v)]

where rd[x] = sum of 1/w on the root->x path. rd is computed with the same
binary-lifting tables as the LCA (a weighted variant), so every node
evaluates its root-path sum in O(log depth) fully-vectorised rounds — the
TPU equivalent of the paper's linear sequential accumulation.

Criticality of an off-tree edge (the sort key, §3.3):  w(e) * R_T(u, v).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.lca import LiftingTables, lca, tree_distance_with_lca


class ResistanceTables(NamedTuple):
    rd: jax.Array  # (n,) float32 — root-path resistance sum


@functools.partial(jax.jit, static_argnames=("n",))
def node_parent_inv_w(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    tree_mask: jax.Array,
    parent: jax.Array,
    n: int,
) -> jax.Array:
    """inv_w[c] = 1/w of the tree edge (c, parent[c]); 0 for the root."""
    child_u = jnp.where(tree_mask & (parent[u] == v), u, -1)
    child_v = jnp.where(tree_mask & (parent[v] == u), v, -1)
    inv = jnp.zeros((n,), dtype=jnp.float32)
    inv = inv.at[jnp.where(child_u >= 0, child_u, n)].set(
        jnp.where(child_u >= 0, 1.0 / w, 0.0), mode="drop"
    )
    inv = inv.at[jnp.where(child_v >= 0, child_v, n)].set(
        jnp.where(child_v >= 0, 1.0 / w, 0.0), mode="drop"
    )
    return inv


@jax.jit
def root_path_sums(t: LiftingTables, inv_w: jax.Array) -> ResistanceTables:
    """rd[x] = sum of inv_w along root->x, via weighted binary lifting."""
    log, n = t.up.shape

    def build(carry, _):
        up_k, ws_k = carry
        ws_next = ws_k + ws_k[up_k]
        up_next = up_k[up_k]
        return (up_next, ws_next), (up_k, ws_k)

    (_, _), (ups, wsums) = jax.lax.scan(
        build, (t.up[0], inv_w), None, length=log
    )

    nodes = jnp.arange(n, dtype=jnp.int32)
    rem = t.depth

    def climb(i, state):
        cur, acc, rem = state
        k = log - 1 - i
        take = (rem >> k) & 1
        acc = acc + jnp.where(take == 1, wsums[k][cur], 0.0)
        cur = jnp.where(take == 1, ups[k][cur], cur)
        return cur, acc, rem & ~(1 << k)

    _, rd, _ = jax.lax.fori_loop(
        0, log, climb, (nodes, jnp.zeros((n,), jnp.float32), rem)
    )
    return ResistanceTables(rd=rd)


@jax.jit
def edge_resistance(
    t: LiftingTables, r: ResistanceTables, u: jax.Array, v: jax.Array,
    edge_lca: jax.Array,
) -> jax.Array:
    return r.rd[u] + r.rd[v] - 2.0 * r.rd[edge_lca]


@jax.jit
def criticality(
    t: LiftingTables,
    r: ResistanceTables,
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    edge_lca: jax.Array,
) -> jax.Array:
    """Spectral criticality w(e) * R_T(e) — the greedy's sort key."""
    return w * edge_resistance(t, r, u, v, edge_lca)


# ---------------------------------------------------------------------------
# Dense ground truth (host / numpy, float64): the O(n^3) pseudoinverse
# formulation the linear pipeline is validated against. Small-n only —
# tests/test_spectral_quality.py uses these to pin the sparsifier's
# *spectral* quality directly, so a refactor cannot silently degrade
# output while staying self-consistent with its own oracle.
# ---------------------------------------------------------------------------

import numpy as np  # noqa: E402  (host-only helpers below)


def dense_laplacian_np(n, u, v, w, mask=None) -> np.ndarray:
    """(n, n) float64 graph Laplacian of the (optionally masked) edges."""
    L = np.zeros((n, n), np.float64)
    if mask is None:
        mask = np.ones(len(u), bool)
    for x, y, ww, keep in zip(np.asarray(u), np.asarray(v),
                              np.asarray(w, np.float64), np.asarray(mask)):
        if not keep:
            continue
        x, y = int(x), int(y)
        L[x, x] += ww
        L[y, y] += ww
        L[x, y] -= ww
        L[y, x] -= ww
    return L


def dense_effective_resistance_np(L_dense: np.ndarray, u, v) -> np.ndarray:
    """Effective resistances R(u_i, v_i) via the Laplacian pseudoinverse.

    R(a, b) = (e_a - e_b)^T L^+ (e_a - e_b) — the textbook definition the
    tree-path sums of `root_path_sums` + LCA reproduce exactly when the
    graph *is* a tree (asserted by the quality tests).
    """
    P = np.linalg.pinv(L_dense, hermitian=True)
    u = np.asarray(u)
    v = np.asarray(v)
    return P[u, u] + P[v, v] - 2.0 * P[u, v]


def spearman_np(a, b) -> float:
    """Tie-aware Spearman rank correlation (no scipy in the pinned
    environment; ties get average ranks, the textbook convention)."""
    def _ranks(x):
        x = np.asarray(x, np.float64)
        _, inv, cnt = np.unique(x, return_inverse=True, return_counts=True)
        start = np.cumsum(cnt) - cnt
        return (start + (cnt - 1) / 2.0)[inv]

    ra, rb = _ranks(a), _ranks(b)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra * ra).sum() * (rb * rb).sum())
    if denom == 0.0:  # constant ranks on either side: define as perfect
        return 1.0
    return float((ra * rb).sum() / denom)


def probe_calibration_np(n, u, v, w, qu, qv, qw, r_hat,
                         mask=None) -> dict:
    """The calibration seam between the solver-free estimator
    (core/spectral_probe.py) and this module's dense pinv oracle.

    Computes the dense ground-truth R(qu_i, qv_i) on the (optionally
    masked) graph and scores `r_hat` against it: Spearman rank
    correlation of the raw resistances AND of the criticality ordering
    (qw · R — the quantity the sparsifier actually sorts by), plus
    relative-error quantiles. Small n only (the point of the seam:
    the estimator earns trust here, then runs where this cannot).
    """
    L = dense_laplacian_np(n, u, v, w, mask=mask)
    r_dense = dense_effective_resistance_np(L, qu, qv)
    r_hat = np.asarray(r_hat, np.float64)
    qw = np.asarray(qw, np.float64)
    rel = np.abs(r_hat - r_dense) / np.maximum(r_dense, 1e-12)
    return dict(
        r_dense=r_dense,
        spearman_er=spearman_np(r_hat, r_dense),
        spearman_crit=spearman_np(qw * r_hat, qw * r_dense),
        med_rel_err=float(np.median(rel)) if len(rel) else 0.0,
        max_rel_err=float(rel.max()) if len(rel) else 0.0,
    )


def spectral_bounds_np(L_full: np.ndarray, L_sub: np.ndarray,
                       tol: float = 1e-9):
    """(lam_min, lam_max) of the pencil x^T L_sub x / x^T L_full x.

    Restricted to range(L_full) (the all-ones null space — and any
    disconnected-component null directions — are projected out): with
    L_full = U diag(d) U^T, W = U_+ diag(d_+^{-1/2}), the pencil spectrum
    is eig(W^T L_sub W). For a subgraph sparsifier 0 <= lam <= 1, and
    lam_min is the quality figure: how much of every quadratic form the
    sparsifier preserves.
    """
    d, U = np.linalg.eigh(L_full)
    keep = d > tol * max(float(d[-1]), 1.0)
    W = U[:, keep] / np.sqrt(d[keep])[None, :]
    M = W.T @ L_sub @ W
    lam = np.linalg.eigvalsh((M + M.T) / 2.0)
    return float(lam[0]), float(lam[-1])
