"""Linear-time radix sort in JAX (LGRASS §3.3, TPU adaptation).

The paper sorts non-negative float64 keys by reinterpreting them as
integers (IEEE-754 order-preserving bit trick) and running an 8-pass
byte-wise radix sort. Our criticality keys are float32, so the TPU port
uses the same trick on uint32 with 4 byte passes (an 8-pass uint64 variant
is provided for f64 fidelity via a (hi, lo) uint32 pair — no x64 needed).

Per pass the positions are computed with the *chunked one-hot* scheme:
split the key stream into chunks of C, build a (C, 256) one-hot, and get
  - the global digit histogram (phase A scan),
  - the stable within-digit rank via exclusive prefix over chunks +
    running per-digit carry (phase B scan).
This maps the scalar bucket counters of the CPU algorithm onto dense
(C, 256) matrix ops — the MXU/VPU-friendly formulation — and is what the
`radix_hist` Pallas kernel implements for the histogram phase.

Everything is O(L) per pass with a 256-wide constant.

Engine dispatch: the chunked one-hot radix formulation is the right
shape for the MXU but a poor fit for CPU (dense 256-wide tiles per
element vs a cache-friendly comparator sort — ~50x on the CI box), so
the public argsorts pick an engine per backend: "radix" on TPU, XLA's
stable sort elsewhere. Both are stable ascending orders of the same
keys, hence the SAME permutation — callers cannot observe the choice
(tests/test_sort.py pins each engine explicitly and asserts equality).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

_CHUNK = 1024
_NBUCKETS = 256

# Symbolic bounds the static range checker (repro.analysis.ranges)
# consumes: every per-pass digit lies in [0, RADIX_MAX_DIGIT], and the
# int32 rank/offset arithmetic (cumsums of per-digit counts) is exact
# for any padded length up to RADIX_RANK_MAX_LEN elements.
RADIX_NBUCKETS = _NBUCKETS
RADIX_MAX_DIGIT = _NBUCKETS - 1
RADIX_RANK_MAX_LEN = 2 ** 31 - 1


def float32_sort_key(x: jax.Array) -> jax.Array:
    """Order-preserving map float32 -> uint32 (IEEE-754 trick, §3.3).

    For x >= 0 this flips only the sign bit; for x < 0 all bits flip, so
    uint comparison == float comparison for any finite input.
    """
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = bits >> 31
    return jnp.where(sign == 1, ~bits, bits | jnp.uint32(0x80000000))


def _chunk_for(m: int) -> int:
    """Static chunk size: shrink the (chunk, 256) one-hot tile for small
    inputs so tiny (batched serving) graphs don't pay the full-width
    fixed cost; identical output for any chunk."""
    c = 64
    while c < _CHUNK and c < m:
        c <<= 1
    return c


def _pad_len(m: int, chunk: int = _CHUNK) -> int:
    return (m + chunk - 1) // chunk * chunk


def _digit_ranks_and_hist(digits: jax.Array, nb: int = _NBUCKETS,
                          chunk: int = _CHUNK):
    """Stable within-digit rank of each element + the global digit
    histogram, from ONE one-hot scan (phases A and B share the tile: the
    running per-digit carry ends as the full histogram)."""
    chunks = digits.reshape(-1, chunk)

    def step(carry, ck):
        onehot = ck[:, None] == jnp.arange(nb, dtype=jnp.int32)[None, :]
        onehot_i = onehot.astype(jnp.int32)
        # exclusive prefix within the chunk, per bucket (sum dtypes are
        # pinned: under x64 numpy-style promotion would widen to int64
        # and break the scan carry)
        within = jnp.cumsum(onehot_i, axis=0, dtype=jnp.int32) - onehot_i
        rank = carry[ck] + jnp.sum(within * onehot_i, axis=1,
                                   dtype=jnp.int32)
        return carry + jnp.sum(onehot_i, axis=0, dtype=jnp.int32), rank

    hist, ranks = jax.lax.scan(step, jnp.zeros((nb,), jnp.int32), chunks)
    return ranks.reshape(-1), hist


def _digit_positions(digits: jax.Array, offsets: jax.Array,
                     nb: int = _NBUCKETS, chunk: int = _CHUNK) -> jax.Array:
    """Stable output position of each element given per-bucket offsets."""
    ranks, _ = _digit_ranks_and_hist(digits, nb, chunk)
    return offsets[digits] + ranks


def bucket_ranks(keys: jax.Array, n_buckets: int,
                 chunk: int = _CHUNK) -> jax.Array:
    """Stable rank of each element within its bucket, O(L * nb / chunk)
    scan of dense (chunk, nb) one-hots. Used by radix passes and by the
    MoE capacity dispatch (rank-in-expert)."""
    m = keys.shape[0]
    lp = (m + chunk - 1) // chunk * chunk
    kp = jnp.full((lp,), n_buckets - 1, jnp.int32).at[:m].set(
        keys.astype(jnp.int32))
    pos = _digit_positions(kp, jnp.zeros((n_buckets,), jnp.int32), n_buckets,
                           chunk)
    return pos[:m]


def _counting_pass(keys_u32: jax.Array, perm: jax.Array, shift: int,
                   m: int, chunk: int = _CHUNK) -> jax.Array:
    """One stable byte pass: reorder `perm` by byte `shift` of keys[perm]."""
    lp = perm.shape[0]
    cur = keys_u32[perm]
    digits = ((cur >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
    # padded tail sorts to the end: give it digit 255 and rely on the fact
    # that real keys never use the pad slot (we mask below instead).
    valid = jnp.arange(lp, dtype=jnp.int32) < m
    digits = jnp.where(valid, digits, _NBUCKETS - 1)
    ranks, hist = _digit_ranks_and_hist(digits, chunk=chunk)
    offsets = jnp.cumsum(hist) - hist  # exclusive
    pos = offsets[digits] + ranks
    out = jnp.zeros((lp,), dtype=perm.dtype).at[pos].set(perm)
    return out


def _default_engine() -> str:
    return "radix" if jax.default_backend() == "tpu" else "xla"


@functools.partial(jax.jit, static_argnames=("engine",))
def radix_argsort_u32(keys: jax.Array, engine: str | None = None) -> jax.Array:
    """Stable ascending argsort of uint32 keys, O(L) on the radix engine.

    engine: "radix" (4 one-hot byte passes), "xla" (backend comparator
    sort), or None for the per-backend default. Identical permutation
    either way (both stable ascending).
    """
    eng = engine or _default_engine()
    if eng == "xla":
        return jnp.argsort(keys, stable=True).astype(jnp.int32)
    m = keys.shape[0]
    chunk = _chunk_for(m)
    lp = _pad_len(m, chunk)
    keys_p = jnp.zeros((lp,), dtype=jnp.uint32).at[:m].set(keys)
    keys_p = keys_p.at[m:].set(jnp.uint32(0xFFFFFFFF))
    perm = jnp.arange(lp, dtype=jnp.int32)
    for shift in (0, 8, 16, 24):
        perm = _counting_pass(keys_p, perm, shift, lp, chunk)  # pads = MAX
    return perm[:m]


@functools.partial(jax.jit, static_argnames=("engine",))
def radix_argsort_u64pair(hi: jax.Array, lo: jax.Array,
                          engine: str | None = None) -> jax.Array:
    """Stable ascending argsort of (hi, lo) uint32 pairs — the paper's
    8-pass INT64 sort without requiring x64 mode (engine as above)."""
    eng = engine or _default_engine()
    if eng == "xla":
        p1 = jnp.argsort(lo, stable=True).astype(jnp.int32)
        p2 = jnp.argsort(hi[p1], stable=True).astype(jnp.int32)
        return p1[p2]
    m = hi.shape[0]
    chunk = _chunk_for(m)
    lp = _pad_len(m, chunk)
    hi_p = jnp.full((lp,), jnp.uint32(0xFFFFFFFF)).at[:m].set(hi)
    lo_p = jnp.full((lp,), jnp.uint32(0xFFFFFFFF)).at[:m].set(lo)
    perm = jnp.arange(lp, dtype=jnp.int32)
    for shift in (0, 8, 16, 24):
        perm = _counting_pass(lo_p, perm, shift, lp, chunk)
    for shift in (0, 8, 16, 24):
        perm = _counting_pass(hi_p, perm, shift, lp, chunk)
    return perm[:m]


@jax.jit
def sort_f32_desc_stable(keys: jax.Array,
                         valid: jax.Array | None = None) -> jax.Array:
    """Permutation sorting float32 keys descending; ties keep input order.

    This is the edge-criticality sort: (criticality desc, edge-id asc).

    valid: optional (L,) bool padding mask (batched pipeline). Invalid
    slots sort after every valid slot — their keys are forced to -inf and
    stability plus the convention that padding occupies the tail indices
    puts them strictly last, so valid slots keep the exact ranks they
    would get in an unpadded sort.
    """
    if valid is not None:
        keys = jnp.where(valid, keys, -jnp.inf)
    k = float32_sort_key(keys)
    return radix_argsort_u32(~k)  # bitwise-not of a monotone map => desc


def block_view(x: jax.Array, chunk: int, fill) -> jax.Array:
    """Pad a (L,) array to a chunk multiple and reshape to (n_blocks, chunk).

    The block-aligned layout both chunked schedulers (phase-1 marking and
    the recovery replay) iterate over: block b holds sorted slots
    [b*chunk, (b+1)*chunk), with the ragged tail padded by `fill` (pick a
    value the consumer's masks neutralise — False for activity masks, 0
    for ids). chunk must be >= 1; L == 0 yields (0, chunk).
    """
    m = x.shape[0]
    n_blocks = -(-m // chunk)
    pad = n_blocks * chunk - m
    padded = jnp.concatenate(
        [x, jnp.full((pad,), fill, dtype=x.dtype)]
    )
    return padded.reshape(n_blocks, chunk)


@jax.jit
def stable_group_sort(group_ids: jax.Array, rank_perm: jax.Array) -> jax.Array:
    """Edges already permuted by criticality rank (`rank_perm`); stable-sort
    that order by uint32 `group_ids` so groups are contiguous and
    criticality-ordered within each group. Returns the composed permutation.
    """
    g = group_ids[rank_perm].astype(jnp.uint32)
    p = radix_argsort_u32(g)
    return rank_perm[p]
