# The paper's primary contribution: LGRASS linear graph spectral
# sparsification, as a composable JAX module. Public API:
from repro.core.graph import (
    Graph,
    official_case,
    powergrid_like_graph,
    random_connected_graph,
)
from repro.core.baseline import BaselineResult, baseline_sparsify, default_budget
from repro.core.sparsify import SparsifyResult, lgrass_sparsify, phase1_device

__all__ = [
    "Graph",
    "official_case",
    "powergrid_like_graph",
    "random_connected_graph",
    "BaselineResult",
    "baseline_sparsify",
    "default_budget",
    "SparsifyResult",
    "lgrass_sparsify",
    "phase1_device",
]
