# The paper's primary contribution: LGRASS linear graph spectral
# sparsification, as a composable JAX module. Public API:
from repro.core.graph import (
    Graph,
    GraphBatch,
    feeder_like_graph,
    official_case,
    powergrid_like_graph,
    random_connected_graph,
)
from repro.core.baseline import BaselineResult, baseline_sparsify, default_budget
from repro.core.pow2 import log2_ceil, next_pow2
from repro.core.recovery import (
    recover_device,
    recover_device_batched,
    recover_host,
)
from repro.core.sparsify import (
    SparsifyResult,
    lgrass_device,
    lgrass_device_batched,
    lgrass_sparsify,
    lgrass_sparsify_batch,
    phase1_device,
    phase1_device_batched,
)
from repro.core.spectral_probe import (
    laplacian_spmv,
    probe_criticality,
    probe_edge_resistance,
    probe_edge_resistance_batched,
    trace_similarity,
)

__all__ = [
    "Graph",
    "GraphBatch",
    "feeder_like_graph",
    "official_case",
    "powergrid_like_graph",
    "random_connected_graph",
    "BaselineResult",
    "baseline_sparsify",
    "default_budget",
    "SparsifyResult",
    "lgrass_device",
    "lgrass_device_batched",
    "lgrass_sparsify",
    "lgrass_sparsify_batch",
    "laplacian_spmv",
    "log2_ceil",
    "next_pow2",
    "phase1_device",
    "phase1_device_batched",
    "probe_criticality",
    "probe_edge_resistance",
    "probe_edge_resistance_batched",
    "recover_device",
    "recover_device_batched",
    "recover_host",
    "trace_similarity",
]
