# The paper's primary contribution: LGRASS linear graph spectral
# sparsification, as a composable JAX module. Public API:
from repro.core.graph import (
    Graph,
    GraphBatch,
    official_case,
    powergrid_like_graph,
    random_connected_graph,
)
from repro.core.baseline import BaselineResult, baseline_sparsify, default_budget
from repro.core.sparsify import (
    SparsifyResult,
    lgrass_sparsify,
    lgrass_sparsify_batch,
    phase1_device,
    phase1_device_batched,
)

__all__ = [
    "Graph",
    "GraphBatch",
    "official_case",
    "powergrid_like_graph",
    "random_connected_graph",
    "BaselineResult",
    "baseline_sparsify",
    "default_budget",
    "SparsifyResult",
    "lgrass_sparsify",
    "lgrass_sparsify_batch",
    "phase1_device",
    "phase1_device_batched",
]
