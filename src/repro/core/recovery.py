"""Recovery of non-crossing edges and their after-effects (Algorithm 6).

Phase 1 (marking.py) resolves crossing edges per independent LCA group.
Non-crossing edges, overflowed groups and the global budget cut are
replayed here sequentially in global criticality order — exactly as the
paper keeps Algorithm 6 a sequential tail even in parallel LGRASS
(Fig. 1c). The replay reuses phase-1 decisions wherever they are provably
final and re-derives them only where a *dirty* flag says an interaction
outside phase 1's model occurred:

  * an accepted non-crossing edge dirties every off-tree edge it covers
    ("enforced"/"withdrawn" propagation, Alg. 6 lines 11-19);
  * a crossing edge whose final decision flips w.r.t. phase 1 dirties the
    later edges of its group (their phase-1 checks consulted a stale
    accepted set);
  * groups that overflowed the K-slot accept table are fully dirty.

Dirty or non-crossing edges are decided by the exact ball-pair test
against the accepted-so-far set, so the result equals the baseline greedy
(tests assert bit-equality against baseline.py on random graphs).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core import _host as H


def recover(
    n: int,
    u: np.ndarray,
    v: np.ndarray,
    tree_mask: np.ndarray,
    parent_t: np.ndarray,
    depth_t: np.ndarray,
    up: np.ndarray,
    beta: np.ndarray,
    crossing: np.ndarray,
    crit_order: np.ndarray,
    phase1_accept: np.ndarray,
    group_of_edge: np.ndarray,
    dirty0: np.ndarray,
    budget: int,
) -> np.ndarray:
    """Returns (L,) bool — final accepted off-tree edges.

    phase1_accept: (L,) bool, meaningful for crossing edges only.
    group_of_edge: (L,) int64 dense group index, -1 for non-crossing.
    dirty0: (L,) bool — initial dirty set (overflowed groups).
    """
    L = len(u)
    offtree = ~tree_mask
    adj = H.tree_adjacency(parent_t, n)
    dirty = dirty0.copy()
    out = np.zeros(L, bool)

    acc_u: list = []
    acc_v: list = []
    acc_b: list = []
    au = np.empty(0, np.int64)
    av = np.empty(0, np.int64)
    ab = np.empty(0, np.int64)
    stale = True

    def covered_by_any(e: int) -> bool:
        nonlocal au, av, ab, stale
        if not acc_u:
            return False
        if stale:
            au = np.array(acc_u, np.int64)
            av = np.array(acc_v, np.int64)
            ab = np.array(acc_b, np.int64)
            stale = False
        x, y = int(u[e]), int(v[e])
        dxu = H.tree_dist_np(up, depth_t, x, au)
        dxv = H.tree_dist_np(up, depth_t, x, av)
        dyu = H.tree_dist_np(up, depth_t, y, au)
        dyv = H.tree_dist_np(up, depth_t, y, av)
        pair = ((dxu <= ab) & (dyv <= ab)) | ((dxv <= ab) & (dyu <= ab))
        return bool(pair.any())

    count = 0
    for e in crit_order:
        e = int(e)
        if count == budget:
            break
        if crossing[e] and not dirty[e]:
            dec = bool(phase1_accept[e])
        else:
            dec = not covered_by_any(e)
        if crossing[e] and dec != bool(phase1_accept[e]):
            # flip: later same-group phase-1 decisions are stale
            dirty |= group_of_edge == group_of_edge[e]
        if dec:
            out[e] = True
            count += 1
            acc_u.append(int(u[e]))
            acc_v.append(int(v[e]))
            acc_b.append(int(beta[e]))
            stale = True
            if not crossing[e]:
                # Alg. 6 after-effects: dirty everything this edge covers
                s1 = H.ball_np(adj, int(u[e]), int(beta[e]))
                s2 = H.ball_np(adj, int(v[e]), int(beta[e]))
                m1 = np.zeros(n, bool)
                m2 = np.zeros(n, bool)
                m1[list(s1)] = True
                m2[list(s2)] = True
                cov = offtree & ((m1[u] & m2[v]) | (m2[u] & m1[v]))
                dirty |= cov
    return out
