"""Recovery of non-crossing edges and their after-effects (Algorithm 6).

Phase 1 (marking.py) resolves crossing edges per independent LCA group.
Non-crossing edges, overflowed groups and the global budget cut are
replayed here in global criticality order. The replay reuses phase-1
decisions wherever they are provably final and re-derives them only where
a *dirty* flag says an interaction outside phase 1's model occurred:

  * an accepted non-crossing edge dirties every off-tree edge it covers
    ("enforced"/"withdrawn" propagation, Alg. 6 lines 11-19);
  * a crossing edge whose final decision flips w.r.t. phase 1 dirties the
    later edges of its group (their phase-1 checks consulted a stale
    accepted set);
  * groups that overflowed the K-slot accept table are fully dirty.

Dirty or non-crossing edges are decided by the exact ball-pair test
against the accepted-so-far set, so the result equals the baseline greedy
(tests assert bit-equality against baseline.py on random graphs).

Two implementations of the identical semantics live here:

  * `recover_host` — the numpy oracle, mirroring the paper's own
    sequential Algorithm 6 tail (Fig. 1c). Kept as the ground truth the
    device program is asserted against.
  * `recover_device` — a jit/vmap-able chunked `lax.scan` over the
    criticality-ordered edge stream. The accepted set lives in a
    budget-bounded (b_cap,) buffer; the ball-pair coverage test is
    vectorised via analytic tree distances (`x in B(c, beta)` iff
    `tree_dist(x, c) <= beta`, so no ball is ever materialised) —
    answered by Euler-tour O(1)-LCA tables rebuilt on device from
    up[0] by default (`use_euler_lca`, the same backend the fused
    program shares), or by binary-lifting climbs — with one batched
    LCA per block of `chunk` edges
    (marking.ball_pair_table, the cover-table helper shared with the
    chunked phase-1 scheduler that later ported this exact scheme)
    answering every block-vs-buffer and block-vs-block query at once;
    and the after-effects dirty propagation is *lazy*: instead of the
    host's eager "dirty every edge this ball pair covers" BFS scatter,
    each edge derives its own dirty bit at processing time from (a) the
    overflow seed, (b) a per-group flip flag maintained with O(1)
    scatters, and (c) coverage by any accepted *non-crossing* buffer
    entry — coverage is time-invariant once the tree is fixed, so
    deferring the test is exact. Decisions are integer comparisons
    throughout, hence bit-identical to the host replay.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import _host as H
from repro.core.lca import LiftingTables, build_euler
from repro.core.marking import ball_pair_table
from repro.core.sort import block_view


def recover_host(
    n: int,
    u: np.ndarray,
    v: np.ndarray,
    tree_mask: np.ndarray,
    parent_t: np.ndarray,
    depth_t: np.ndarray,
    up: np.ndarray,
    beta: np.ndarray,
    crossing: np.ndarray,
    crit_order: np.ndarray,
    phase1_accept: np.ndarray,
    group_of_edge: np.ndarray,
    dirty0: np.ndarray,
    budget: int,
) -> np.ndarray:
    """Returns (L,) bool — final accepted off-tree edges.

    phase1_accept: (L,) bool, meaningful for crossing edges only.
    group_of_edge: (L,) int64 dense group index, -1 for non-crossing.
    dirty0: (L,) bool — initial dirty set (overflowed groups).
    """
    L = len(u)
    offtree = ~tree_mask
    adj = H.tree_adjacency(parent_t, n)
    dirty = dirty0.copy()
    out = np.zeros(L, bool)

    # accepted set: preallocated at the budget bound (the greedy stops at
    # `budget` accepts, so no growth/rebuild ever happens mid-replay)
    cap = max(int(budget), 1)
    acc_u = np.zeros(cap, np.int64)
    acc_v = np.zeros(cap, np.int64)
    acc_b = np.zeros(cap, np.int64)

    def covered_by_any(e: int, count: int) -> bool:
        if count == 0:
            return False
        au, av, ab = acc_u[:count], acc_v[:count], acc_b[:count]
        x, y = int(u[e]), int(v[e])
        dxu = H.tree_dist_np(up, depth_t, x, au)
        dxv = H.tree_dist_np(up, depth_t, x, av)
        dyu = H.tree_dist_np(up, depth_t, y, au)
        dyv = H.tree_dist_np(up, depth_t, y, av)
        pair = ((dxu <= ab) & (dyv <= ab)) | ((dxv <= ab) & (dyu <= ab))
        return bool(pair.any())

    count = 0
    for e in crit_order:
        e = int(e)
        if count == budget:
            break
        if crossing[e] and not dirty[e]:
            dec = bool(phase1_accept[e])
        else:
            dec = not covered_by_any(e, count)
        if crossing[e] and dec != bool(phase1_accept[e]):
            # flip: later same-group phase-1 decisions are stale
            dirty |= group_of_edge == group_of_edge[e]
        if dec:
            out[e] = True
            acc_u[count] = int(u[e])
            acc_v[count] = int(v[e])
            acc_b[count] = int(beta[e])
            count += 1
            if not crossing[e]:
                # Alg. 6 after-effects: dirty everything this edge covers
                s1 = H.ball_np(adj, int(u[e]), int(beta[e]))
                s2 = H.ball_np(adj, int(v[e]), int(beta[e]))
                m1 = np.zeros(n, bool)
                m2 = np.zeros(n, bool)
                m1[list(s1)] = True
                m2[list(s2)] = True
                cov = offtree & ((m1[u] & m2[v]) | (m2[u] & m1[v]))
                dirty |= cov
    return out


# Backwards-compatible name (distributed tests drive the oracle directly).
recover = recover_host


def _recover_scan(
    t: LiftingTables,
    u: jax.Array,
    v: jax.Array,
    beta: jax.Array,
    offtree: jax.Array,
    crossing: jax.Array,
    order: jax.Array,
    phase1_accept: jax.Array,
    group_of_edge: jax.Array,
    dirty0: jax.Array,
    budget: jax.Array,
    b_cap: int,
    use_tree_kernel: bool = False,
    chunk: int = 32,
    euler=None,
):
    """The device replay: a chunked two-level lax.scan over rank slots.

    `euler`: optional lca.EulerLCA tables — when given (the fused
    program passes the ones it already built for chunked marking), the
    per-block cover tables answer each distance in O(1) gathers instead
    of O(log n) lifting climbs; decisions are identical integers.

    `order` is a full (L,) permutation — (crit desc, id asc) with tree /
    padding slots forced to -inf keys, so they trail every off-tree edge
    and are skipped via the gathered `offtree` flag. `budget` is a traced
    scalar; `b_cap` (static) bounds the accept buffer and must satisfy
    b_cap >= budget (the greedy never holds more than `budget` accepts).
    Because `budget` is traced, that precondition cannot raise here; it
    is enforced by clamping budget to b_cap — the result is then exact
    for the clamped budget instead of silently corrupting the buffer
    (the `lgrass_sparsify(_batch)` wrappers validate and raise on the
    host side before ever reaching this).

    Scheduling: slots are processed in blocks of `chunk`. Per block, ONE
    batched LCA evaluates the cover table of all block edges against
    (a) the buffer snapshot and (b) every other block edge — exploiting
    the pdGRASS observation that the sweep's interactions are local. The
    inner scan then replays the block's decisions with pure table
    lookups: a buffer slot filled before the block reads column `slot`,
    a slot filled mid-block by block edge j reads column b_cap + j
    (`buf_idx` tracks which). Group-flip dirt is a per-*group* flag
    updated with O(1) scatters (index L is the never-set parking slot
    for non-crossing edges). Distances are integers, so chunking changes
    nothing observable: decisions are bit-identical to the host replay.

    The outer loop is a while_loop gated on `cnt < budget`: once the
    budget is exhausted nothing later in the stream can change any
    output (the host replay breaks out at the same point), so the
    common case — budgets of a few percent of n, filled within the top
    criticality ranks — touches only the leading blocks. Under vmap the
    loop runs the union of the lanes' needed blocks, with finished
    lanes' carries frozen by the batching rule.

    Returns (accepted (L,) bool, n_accepted int32).
    """
    L = u.shape[0]
    if L == 0:  # isolated-node graph: nothing to replay
        return jnp.zeros((0,), bool), jnp.int32(0)
    budget = jnp.minimum(jnp.asarray(budget, jnp.int32), jnp.int32(b_cap))
    c = max(min(chunk, L), 1)
    n_blocks = -(-L // c)
    order_pad = block_view(order.astype(jnp.int32), c, 0)
    svalid_pad = block_view(jnp.ones((L,), bool), c, False)
    occ_iota = jnp.arange(b_cap, dtype=jnp.int32)

    def inner(carry, xs):
        buf_u, buf_v, buf_b, buf_nc, buf_idx, cnt, gflag, out = carry
        e, a0, pair_row, i = xs
        active = a0 & (cnt < budget)

        pair_k = pair_row[buf_idx]       # (b_cap,) per-slot cover bits
        occ = occ_iota < cnt
        cov_any = jnp.any(pair_k & occ)
        cov_nc = jnp.any(pair_k & occ & buf_nc)

        cr = crossing[e]
        g = group_of_edge[e]
        gsafe = jnp.where(g < 0, L, g).astype(jnp.int32)
        dirty_e = dirty0[e] | gflag[gsafe] | cov_nc
        dec = active & jnp.where(cr & ~dirty_e, phase1_accept[e], ~cov_any)

        # flip w.r.t. phase 1: dirty the rest of the group (O(1) scatter)
        flip = active & cr & (dec != phase1_accept[e])
        gflag = gflag.at[gsafe].max(flip)

        out = out.at[e].max(dec)  # max: padding re-visits edge id 0
        slot = jnp.minimum(cnt, b_cap - 1)
        x = jnp.where(active, u[e], 0).astype(jnp.int32)
        y = jnp.where(active, v[e], 0).astype(jnp.int32)
        buf_u = buf_u.at[slot].set(jnp.where(dec, x, buf_u[slot]))
        buf_v = buf_v.at[slot].set(jnp.where(dec, y, buf_v[slot]))
        buf_b = buf_b.at[slot].set(
            jnp.where(dec, beta[e].astype(jnp.int32), buf_b[slot])
        )
        buf_nc = buf_nc.at[slot].set(jnp.where(dec, ~cr, buf_nc[slot]))
        blk_col = jnp.int32(b_cap) + i
        buf_idx = buf_idx.at[slot].set(
            jnp.where(dec, blk_col, buf_idx[slot])
        )
        cnt = cnt + dec.astype(jnp.int32)
        return (buf_u, buf_v, buf_b, buf_nc, buf_idx, cnt, gflag, out), None

    def cond(state):
        blk, _, _, _, _, cnt, _, _ = state
        return (blk < n_blocks) & (cnt < budget)

    def outer(state):
        blk, buf_u, buf_v, buf_b, buf_nc, cnt, gflag, out = state
        eids = jax.lax.dynamic_index_in_dim(order_pad, blk, keepdims=False)
        svalid = jax.lax.dynamic_index_in_dim(svalid_pad, blk,
                                              keepdims=False)
        a0 = svalid & offtree[eids]
        bx = jnp.where(a0, u[eids], 0).astype(jnp.int32)
        by = jnp.where(a0, v[eids], 0).astype(jnp.int32)
        # one fused cover table: snapshot buffer ++ block endpoints
        cols_u = jnp.concatenate([buf_u, bx])
        cols_v = jnp.concatenate([buf_v, by])
        cols_b = jnp.concatenate([buf_b, beta[eids].astype(jnp.int32)])
        pair_tbl = ball_pair_table(t, bx, by, cols_u, cols_v, cols_b,
                                   use_tree_kernel, euler)
        (buf_u, buf_v, buf_b, buf_nc, _, cnt, gflag, out), _ = jax.lax.scan(
            inner,
            (buf_u, buf_v, buf_b, buf_nc,
             jnp.arange(b_cap, dtype=jnp.int32), cnt, gflag, out),
            (eids, a0, pair_tbl, jnp.arange(c, dtype=jnp.int32)),
        )
        return (blk + 1, buf_u, buf_v, buf_b, buf_nc, cnt, gflag, out)

    init = (
        jnp.int32(0),                          # block index
        jnp.zeros((b_cap,), jnp.int32),        # buf_u
        jnp.zeros((b_cap,), jnp.int32),        # buf_v
        jnp.full((b_cap,), -1, jnp.int32),     # buf_b (-1: matches nothing)
        jnp.zeros((b_cap,), bool),             # buf_nc (non-crossing entry)
        jnp.int32(0),                          # cnt
        jnp.zeros((L + 1,), bool),             # per-group flip flag
        jnp.zeros((L,), bool),                 # out
    )
    _, _, _, _, _, cnt, _, out = jax.lax.while_loop(cond, outer, init)
    return out, cnt


def _euler_from_lifting(up: jax.Array, depth_t: jax.Array):
    """Rebuild the Euler-tour O(1)-LCA tables from lifting-table inputs.

    The standalone recovery entries only receive `up`/`depth_t`, so the
    tree shape the fused program already had is reconstructed on device:
    `parent` is up[0] with its self-loops (root, unreachable padding)
    mapped back to -1, and the root is the unique depth-0 node
    (`argmin` — padding carries INF depth, so the real root always
    wins). One `build_euler` then gives the exact tables the fused
    pipeline shares with its replay; vmap-safe (pure gathers/scatters).
    """
    n = up.shape[-1]
    nodes = jnp.arange(n, dtype=jnp.int32)
    parent = jnp.where(up[0] == nodes, -1, up[0])
    root = jnp.argmin(depth_t).astype(jnp.int32)
    return build_euler(parent, depth_t, root, n)


@functools.partial(jax.jit,
                   static_argnames=("b_cap", "use_tree_kernel", "chunk",
                                    "use_euler_lca"))
def recover_device(
    up: jax.Array,
    depth_t: jax.Array,
    u: jax.Array,
    v: jax.Array,
    beta: jax.Array,
    tree_mask: jax.Array,
    crossing: jax.Array,
    order: jax.Array,
    phase1_accept: jax.Array,
    group_of_edge: jax.Array,
    dirty0: jax.Array,
    budget: jax.Array,
    b_cap: int,
    edge_valid: jax.Array | None = None,
    use_tree_kernel: bool = False,
    chunk: int = 32,
    use_euler_lca: bool = True,
):
    """Standalone jitted recovery tail (the unit bench_recovery.py times).

    Same argument conventions as `recover_host` except the order is the
    full (L,) sort permutation and `budget` is a device scalar. Returns
    (accepted (L,) bool, n_accepted int32 scalar).

    use_euler_lca (default on) reconstructs the tree from `up[0]` and
    builds the Euler-tour O(1)-LCA tables on device, so the cover
    tables stop climbing the lifting tables — the same backend the
    fused `lgrass_device` replay uses (decisions are identical
    integers; parity vs `recover_host` in tests/test_recovery_device.py).
    The Pallas kernel path takes precedence, as everywhere else.
    """
    t = LiftingTables(up=up, depth=depth_t)
    euler = None
    if use_euler_lca and not use_tree_kernel:
        euler = _euler_from_lifting(up, depth_t)
    offtree = ~tree_mask if edge_valid is None else (~tree_mask) & edge_valid
    return _recover_scan(
        t, u, v, beta, offtree, crossing, order, phase1_accept,
        group_of_edge, dirty0, jnp.asarray(budget, jnp.int32), b_cap,
        use_tree_kernel, chunk, euler,
    )


@functools.partial(jax.jit,
                   static_argnames=("b_cap", "use_tree_kernel", "chunk",
                                    "use_euler_lca"))
def recover_device_batched(
    up: jax.Array,
    depth_t: jax.Array,
    u: jax.Array,
    v: jax.Array,
    beta: jax.Array,
    tree_mask: jax.Array,
    crossing: jax.Array,
    order: jax.Array,
    phase1_accept: jax.Array,
    group_of_edge: jax.Array,
    dirty0: jax.Array,
    budget: jax.Array,
    b_cap: int,
    edge_valid: jax.Array | None = None,
    use_tree_kernel: bool = False,
    chunk: int = 32,
    use_euler_lca: bool = True,
):
    """`recover_device` vmapped over a leading batch axis.

    All array args carry a (B, ...) batch dimension (`budget` is (B,)).
    One dispatch replays every graph's recovery — the standalone unit
    for pipelines that keep phase-1 outputs device-resident, and the one
    bench_recovery.py times against the sync + per-graph host loop.
    Each lane rebuilds its own Euler tables from `up[0]` (see
    `recover_device`); the build is plain gathers/scatters, so the whole
    reconstruction vmaps into the one dispatch.
    """
    def one(bup, bdep, bu, bv, bbeta, btree, bcross, border, bacc, bgrp,
            bdirty, bb, bev):
        t = LiftingTables(up=bup, depth=bdep)
        euler = None
        if use_euler_lca and not use_tree_kernel:
            euler = _euler_from_lifting(bup, bdep)
        return _recover_scan(
            t, bu, bv, bbeta, (~btree) & bev, bcross, border, bacc, bgrp,
            bdirty, bb, b_cap, use_tree_kernel, chunk, euler,
        )

    if edge_valid is None:  # all-true mask ≡ the unmasked offtree
        edge_valid = jnp.ones_like(tree_mask, dtype=bool)
    return jax.vmap(one)(
        up, depth_t, u, v, beta, tree_mask, crossing, order,
        phase1_accept, group_of_edge, dirty0,
        jnp.asarray(budget, jnp.int32), edge_valid)
