"""Graph containers and generators for LGRASS.

Edges are stored as parallel arrays (u, v, w). The graph is undirected,
connected, simple (no self loops / multi edges). Node ids are 0..n-1.

Conventions shared by the python oracle (`baseline.py`) and the JAX
implementation (`sparsify.py`) — these pin down every tie-break so the two
implementations are bit-identical:

  * root            = node with maximum degree, ties -> smallest id.
  * BFS parent rule = smallest-id neighbour in the previous level.
  * effective weight eff(e) = w(e) * (depth[u] + depth[v] + 1.0)
    with depth from the *graph* BFS (feGRASS-style depth-scaled weight).
  * spanning tree   = MAXIMUM spanning tree under (eff desc, edge-id asc)
    total order (unique because the order is total).
  * criticality     = w(e) * R_tree(u, v) for off-tree e, processed in
    (criticality desc, edge-id asc) order.
  * beta(e)         = max(min(depth_t[u], depth_t[v]) - depth_t[lca], 1)
    with depth_t from the *tree* BFS rooted at `root`.
  * ball(u, b)      = nodes with tree distance (hops) <= b from u.
  * greedy          = accept edge iff not marked; accepted edge marks all
    off-tree edges (x, y) with (x in B(u), y in B(v)) or swapped; stop
    after `budget` accepts.

Padding / bucketing conventions (batched pipeline, `GraphBatch`):

  * a batch pads B graphs to shared (n_max, L_max); node padding is
    implicit (ids n..n_max-1 are simply never referenced by real edges).
  * padding edges are self loops on node 0 with sentinel weight 0.0 and
    edge_valid == False; every device stage threads the mask so padding
    edges never gain degree, never enter the spanning tree, and never
    join a crossing group — real slots are bit-identical to an unpadded
    single-graph run (tests/test_batch.py asserts this).
  * real edges always occupy the leading L slots, so padding slots sort
    strictly after every real slot under the stable (key desc, id asc)
    orders above.
  * the serving layer buckets (n_max, L_max) up to powers of two
    (serve/sparsify_service.py) so the number of distinct compiled
    shapes is logarithmic in the size range.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    """Undirected weighted graph in edge-list form (host/numpy side)."""

    n: int
    u: np.ndarray  # (L,) int32
    v: np.ndarray  # (L,) int32
    w: np.ndarray  # (L,) float32, positive

    @property
    def m(self) -> int:
        return int(self.u.shape[0])

    def degrees(self) -> np.ndarray:
        deg = np.zeros(self.n, dtype=np.int64)
        np.add.at(deg, self.u, 1)
        np.add.at(deg, self.v, 1)
        return deg

    def root(self) -> int:
        """Max-degree node, ties -> smallest id."""
        deg = self.degrees()
        return int(np.argmax(deg))  # argmax returns first (smallest id) max

    def csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Symmetrised CSR: (offsets[n+1], nbrs[2L], eid[2L])."""
        src = np.concatenate([self.u, self.v])
        dst = np.concatenate([self.v, self.u])
        eid = np.concatenate([np.arange(self.m), np.arange(self.m)])
        order = np.lexsort((dst, src))
        src, dst, eid = src[order], dst[order], eid[order]
        offsets = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(offsets, src + 1, 1)
        offsets = np.cumsum(offsets)
        return offsets, dst.astype(np.int32), eid.astype(np.int32)

    def validate(self) -> None:
        assert self.u.shape == self.v.shape == self.w.shape
        assert np.all(self.u != self.v), "self loops not allowed"
        assert np.all(self.w > 0), "weights must be positive"
        key = np.minimum(self.u, self.v) * np.int64(self.n) + np.maximum(
            self.u, self.v
        )
        assert len(np.unique(key)) == self.m, "multi-edges not allowed"


PAD_ENDPOINT = 0     # padding edges are self loops on node 0
PAD_WEIGHT = 0.0     # sentinel: real weights are strictly positive


def trivial_graph() -> Graph:
    """The minimal legal graph: one node, zero edges.

    Two jobs: (a) the canonical degenerate input — the pipeline returns
    empty masks for it through every path (direct, batched, service);
    (b) the serving plane's batch-axis placeholder. A placeholder must
    fit EVERY bucket, including (n_bucket=1, L_bucket=1) when the
    service floors are lowered, so it has to be the smallest graph there
    is — an (n=2, m=1) filler used to crash small buckets with
    "bucket too small" (see tests/test_service_plane.py).
    """
    return Graph(n=1, u=np.zeros(0, np.int32), v=np.zeros(0, np.int32),
                 w=np.zeros(0, np.float32))


@dataclasses.dataclass
class GraphBatch:
    """B graphs padded to shared (n_max, L_max) for one device dispatch.

    Edge arrays are (B, L_max); `edge_valid` marks real slots, padding
    slots hold (PAD_ENDPOINT, PAD_ENDPOINT, PAD_WEIGHT). Real edges of
    graph i occupy slots 0..m_i-1 (see the padding conventions in the
    module docstring). The original `Graph` objects are kept so the host
    recovery tail can slice results back to per-graph shapes.
    """

    graphs: list
    n_max: int
    L_max: int
    u: np.ndarray           # (B, L_max) int32
    v: np.ndarray           # (B, L_max) int32
    w: np.ndarray           # (B, L_max) float32
    edge_valid: np.ndarray  # (B, L_max) bool
    n_real: np.ndarray      # (B,) int32 — true node counts
    m_real: np.ndarray      # (B,) int32 — true edge counts

    @property
    def batch_size(self) -> int:
        return len(self.graphs)

    @classmethod
    def from_graphs(
        cls,
        graphs,
        n_max: Optional[int] = None,
        L_max: Optional[int] = None,
    ) -> "GraphBatch":
        """Pad `graphs` to a shared bucket; n_max/L_max may round the
        bucket up (serving uses powers of two to bound recompiles)."""
        graphs = list(graphs)
        if not graphs:
            raise ValueError("empty batch")
        need_n = max(g.n for g in graphs)
        need_L = max(g.m for g in graphs)
        n_max = need_n if n_max is None else int(n_max)
        L_max = need_L if L_max is None else int(L_max)
        if n_max < need_n or L_max < need_L:
            raise ValueError(
                f"bucket ({n_max}, {L_max}) too small for ({need_n}, {need_L})"
            )
        B = len(graphs)
        u = np.full((B, L_max), PAD_ENDPOINT, np.int32)
        v = np.full((B, L_max), PAD_ENDPOINT, np.int32)
        w = np.full((B, L_max), PAD_WEIGHT, np.float32)
        edge_valid = np.zeros((B, L_max), bool)
        for i, g in enumerate(graphs):
            u[i, : g.m] = g.u
            v[i, : g.m] = g.v
            w[i, : g.m] = g.w
            edge_valid[i, : g.m] = True
        return cls(
            graphs=graphs,
            n_max=n_max,
            L_max=L_max,
            u=u,
            v=v,
            w=w,
            edge_valid=edge_valid,
            n_real=np.array([g.n for g in graphs], np.int32),
            m_real=np.array([g.m for g in graphs], np.int32),
        )


def random_connected_graph(
    n: int,
    extra_edges: int,
    seed: int = 0,
    weight: str = "lognormal",
) -> Graph:
    """Random spanning tree + `extra_edges` distinct chords."""
    rng = np.random.default_rng(seed)
    # random spanning tree: attach node i to a uniform previous node
    parents = np.array([rng.integers(0, i) for i in range(1, n)])
    tu = np.arange(1, n, dtype=np.int64)
    tv = parents.astype(np.int64)
    existing = set(zip(np.minimum(tu, tv).tolist(), np.maximum(tu, tv).tolist()))
    cu, cv = [], []
    max_extra = n * (n - 1) // 2 - (n - 1)
    extra_edges = min(extra_edges, max_extra)
    while len(cu) < extra_edges:
        k = extra_edges - len(cu)
        a = rng.integers(0, n, size=2 * k + 8)
        b = rng.integers(0, n, size=2 * k + 8)
        for x, y in zip(a.tolist(), b.tolist()):
            if x == y:
                continue
            key = (min(x, y), max(x, y))
            if key in existing:
                continue
            existing.add(key)
            cu.append(x)
            cv.append(y)
            if len(cu) == extra_edges:
                break
    u = np.concatenate([tu, np.array(cu, dtype=np.int64)])
    v = np.concatenate([tv, np.array(cv, dtype=np.int64)])
    m = len(u)
    if weight == "lognormal":
        w = rng.lognormal(mean=0.0, sigma=1.0, size=m)
    elif weight == "uniform":
        w = rng.uniform(0.5, 2.0, size=m)
    elif weight == "ties":  # many duplicate weights to stress tie-breaks
        w = rng.integers(1, 4, size=m).astype(np.float64)
    else:
        raise ValueError(weight)
    # shuffle edge order so edge-id tie-breaks are exercised
    perm = rng.permutation(m)
    g = Graph(n=n, u=u[perm].astype(np.int32), v=v[perm].astype(np.int32),
              w=w[perm].astype(np.float32))
    g.validate()
    return g


def feeder_like_graph(
    n: int,
    chords: int,
    span: int = 24,
    seed: int = 0,
) -> Graph:
    """Radial-feeder topology: a chain with `chords` local shortcuts.

    Distribution networks are chain-heavy; on a chain, a chord (i, j)
    has its shallower endpoint as the LCA, so almost every off-tree edge
    is NON-crossing — phase 1 has nothing to decide and the Algorithm-6
    recovery replay does all the work. This is the recovery-dominated
    regime (the workload bench_recovery.py stresses); the parity tests
    use it to hammer the non-crossing / after-effects paths.
    """
    rng = np.random.default_rng(seed)
    span = min(max(span, 2), n - 1)
    tu = np.arange(n - 1, dtype=np.int64)
    tv = np.arange(1, n, dtype=np.int64)
    seen = set(zip(tu.tolist(), tv.tolist()))
    cu, cv = [], []
    # the generator only reaches pairs with 2 <= j - i <= span; clamping
    # to the all-pairs bound would let the rejection loop spin forever
    max_chords = sum(n - d for d in range(2, span + 1))
    chords = min(chords, max_chords)
    while len(cu) < chords:
        i = int(rng.integers(0, n - 2))
        j = min(i + int(rng.integers(2, span + 1)), n - 1)
        key = (min(i, j), max(i, j))
        if i == j or key in seen:
            continue
        seen.add(key)
        cu.append(i)
        cv.append(j)
    u = np.concatenate([tu, np.array(cu, dtype=np.int64)])
    v = np.concatenate([tv, np.array(cv, dtype=np.int64)])
    w = rng.lognormal(0.0, 1.0, size=len(u))
    perm = rng.permutation(len(u))
    g = Graph(n=n, u=u[perm].astype(np.int32), v=v[perm].astype(np.int32),
              w=w[perm].astype(np.float32))
    g.validate()
    return g


def powergrid_like_graph(n_side: int, chord_frac: float = 0.25,
                         seed: int = 0) -> Graph:
    """2-D grid (power-grid-ish topology, as in the IPCC cases) + chords."""
    rng = np.random.default_rng(seed)
    n = n_side * n_side
    idx = np.arange(n).reshape(n_side, n_side)
    hu = idx[:, :-1].ravel()
    hv = idx[:, 1:].ravel()
    vu = idx[:-1, :].ravel()
    vv = idx[1:, :].ravel()
    u = np.concatenate([hu, vu])
    v = np.concatenate([hv, vv])
    existing = set(zip(np.minimum(u, v).tolist(), np.maximum(u, v).tolist()))
    n_chords = int(chord_frac * n)
    cu, cv = [], []
    while len(cu) < n_chords:
        x, y = int(rng.integers(0, n)), int(rng.integers(0, n))
        if x == y:
            continue
        key = (min(x, y), max(x, y))
        if key in existing:
            continue
        existing.add(key)
        cu.append(x)
        cv.append(y)
    u = np.concatenate([u, np.array(cu, dtype=np.int64)])
    v = np.concatenate([v, np.array(cv, dtype=np.int64)])
    w = rng.lognormal(0.0, 0.5, size=len(u))
    perm = rng.permutation(len(u))
    g = Graph(n=n, u=u[perm].astype(np.int32), v=v[perm].astype(np.int32),
              w=w[perm].astype(np.float32))
    g.validate()
    return g


# The three official IPCC cases are 4K / 7K / 16K nodes. We reconstruct
# equivalently-sized synthetic cases (the official inputs are not public).
OFFICIAL_CASE_SHAPES = {
    "case1": dict(n_side=64, chord_frac=0.25, seed=101),   # ~4K nodes
    "case2": dict(n_side=84, chord_frac=0.20, seed=202),   # ~7K nodes
    "case3": dict(n_side=127, chord_frac=0.25, seed=303),  # ~16K nodes
}


def official_case(name: str) -> Graph:
    return powergrid_like_graph(**OFFICIAL_CASE_SHAPES[name])
