"""Level-synchronous BFS in JAX (TPU adaptation of LGRASS §4.4).

The paper's parallel BFS uses concurrent queues + atomics on a CPU. The
TPU-native equivalent is frontier *vectorisation*: each level is one
edge-parallel relaxation over the full edge list (dense compute, no
queues), which is exactly what the VPU wants. Work is O(L) per level,
O(L * depth) total; for the power-grid-like inputs of the task depth is
O(sqrt(N)) and every level is a fully-vectorised map.

The parent rule is deterministic (smallest-id neighbour in the previous
level) so the python oracle and this implementation build identical trees.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

INF = jnp.iinfo(jnp.int32).max


@functools.partial(jax.jit, static_argnames=("n",))
def bfs(
    u: jax.Array,
    v: jax.Array,
    n: int,
    root: jax.Array,
    edge_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """BFS over the undirected edge list from `root`.

    Args:
        u, v: (L,) int32 endpoints.
        n: number of nodes (static).
        root: scalar int32 root node.
        edge_mask: optional (L,) bool — True edges participate (used to run
            BFS restricted to the spanning tree without rebuilding CSR).

    Returns:
        depth:  (n,) int32, INF for unreachable.
        parent: (n,) int32, -1 for root / unreachable.
    """
    src = jnp.concatenate([u, v])
    dst = jnp.concatenate([v, u])
    if edge_mask is not None:
        emask = jnp.concatenate([edge_mask, edge_mask])
    else:
        emask = jnp.ones_like(src, dtype=bool)

    depth0 = jnp.full((n,), INF, dtype=jnp.int32).at[root].set(0)
    parent0 = jnp.full((n,), -1, dtype=jnp.int32)
    frontier0 = jnp.zeros((n,), dtype=bool).at[root].set(True)

    def cond(state):
        _, _, frontier, _ = state
        return jnp.any(frontier)

    def body(state):
        depth, parent, frontier, level = state
        active = frontier[src] & emask
        # candidate parent for each destination: smallest active source id
        cand = jnp.full((n,), INF, dtype=jnp.int32)
        cand = cand.at[dst].min(jnp.where(active, src, INF))
        newly = (cand != INF) & (depth == INF)
        parent = jnp.where(newly, cand, parent)
        depth = jnp.where(newly, level + 1, depth)
        return depth, parent, newly, level + 1

    depth, parent, _, _ = jax.lax.while_loop(
        cond, body, (depth0, parent0, frontier0, jnp.int32(0))
    )
    return depth, parent


@functools.partial(jax.jit, static_argnames=("n",))
def degrees(
    u: jax.Array, v: jax.Array, n: int, edge_valid: Optional[jax.Array] = None
) -> jax.Array:
    one = (
        jnp.ones_like(u)
        if edge_valid is None
        else edge_valid.astype(jnp.int32)
    )
    deg = jnp.zeros((n,), dtype=jnp.int32)
    deg = deg.at[u].add(one)
    deg = deg.at[v].add(one)
    return deg


@functools.partial(jax.jit, static_argnames=("n",))
def select_root(
    u: jax.Array, v: jax.Array, n: int, edge_valid: Optional[jax.Array] = None
) -> jax.Array:
    """Max-degree node, ties -> smallest id (matches Graph.root()).

    edge_valid: optional (L,) padding mask — padding edges contribute no
    degree, so padded nodes (degree 0) can never win against any node of
    the real, connected graph.
    """
    deg = degrees(u, v, n, edge_valid)
    return jnp.argmax(deg).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n",))
def effective_weights(
    u: jax.Array, v: jax.Array, w: jax.Array, depth: jax.Array, n: int
) -> jax.Array:
    """feGRASS-style depth-scaled effective weight (the EFF subroutine).

    eff(e) = w(e) * (depth[u] + depth[v] + 1). Any fixed monotone
    combination works for the pipeline; this one is shared with the oracle.
    """
    d = depth.astype(jnp.float32)
    return w * (d[u] + d[v] + 1.0)
