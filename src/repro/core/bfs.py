"""BFS engines in JAX (TPU adaptation of LGRASS §4.4).

The paper's parallel BFS uses concurrent queues + atomics on a CPU;
there is no TPU analogue for dynamic work lists. Two dense engines live
here, selected by ``engine`` and bit-identical in output
(tests/test_bfs_doubling.py):

  * ``engine="levels"`` — frontier vectorisation: each level is one
    edge-parallel relaxation over the full edge list. O(L) work per
    level, O(diameter) tiny while_loop rounds: the right shape when the
    diameter is O(sqrt N) (power-grid cases), pathological on
    chain-heavy feeder inputs where the diameter is O(N) and every
    round is dispatch-overhead-bound.
  * ``engine="doubling"`` (default) — hop-doubling: each round fuses an
    edge-parallel Bellman–Ford relaxation with pointer doubling over
    the tentative-depth forest, so depth information jumps 2^k-length
    chains per round instead of one hop. Three pointer families carry
    the doubling (see ``bfs_doubling``); the loop runs to the
    relaxation fixpoint, which is reached in O(log n) rounds on
    chain-like inputs and is *provably exact* on every input: tentative
    depths are always upper bounds on the true BFS depth, and any
    relaxation fixpoint of upper bounds equals the true depth. The
    deterministic smallest-id parent is derived afterwards in ONE
    edge-parallel pass — exact depths uniquely determine the parent
    under the shared rule (parent = smallest-id neighbour one level
    up), so depth AND parent equal the level-sync engine bit for bit.

For the *tree-restricted* second pass of the pipeline no fixpoint
iteration is needed at all: ``root_tree`` roots the spanning tree in a
fixed O(log n)-round program by materialising the Euler tour directly
from the undirected tree edge list (per-arc successor pointers +
pointer-doubling list ranking, the same machinery as
``lca.build_euler``) and reading depths off a prefix sum over the tour.

Both engines and the tree path thread the optional edge mask, never
index with booleans, and keep every shape static — safe under jit AND
vmap (the padded ``GraphBatch`` pipeline).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.pow2 import log2_ceil as _log2_ceil

INF = jnp.iinfo(jnp.int32).max

BFS_ENGINES = ("doubling", "levels")


def packed_key_bound(n: int) -> int:
    """Largest packed relaxation key `bfs_doubling` can produce at `n`.

    The fused scatter-min key is dist·(n+1) + id with dist clamped to
    [0, n] and id in [0, n]; the maximum is n·(n+1) + n = (n+1)² − 1.
    This is the symbolic bound the static range checker
    (repro.analysis.ranges) re-derives from the traced program.
    """
    return (n + 1) * (n + 1) - 1


# Largest n for which the packed key provably fits int32:
# (n+1)² − 1 <= INT32_MAX  <=>  n <= isqrt(2³¹) − 1  ==  46339.
# Beyond this the relaxation runs unpacked as two scatter-mins
# (bit-identical, one extra scatter per round). Exported so the range
# checker asserts the switch point instead of trusting an inlined magic
# number; tests/test_bfs_doubling.py pins both sides of the boundary.
PACKED_KEY_MAX_N = math.isqrt(2 ** 31) - 1

# Largest n for which `root_tree_euler` can pack an arc's (tail, head)
# pair into one u32 radix key (16 bits each); beyond it the u64 pair
# sort runs instead. Same contract: exported for the range checker.
EULER_PACK_MAX_N = 0xFFFF


def finite_depth(depth: jax.Array) -> jax.Array:
    """Clamp unreachable (INF) BFS depths to 0.

    The single guard every consumer of raw BFS depths goes through:
    INT32_MAX cast to float32 is ≈2.1e9 and silently poisons any
    arithmetic it touches (effective weights, depth-bound estimates).
    Disconnected inputs are legal for the BFS stage, so the clamp lives
    here, once, instead of ad hoc at call sites.
    """
    return jnp.where(depth == INF, 0, depth)


def bfs(
    u: jax.Array,
    v: jax.Array,
    n: int,
    root: jax.Array,
    edge_mask: Optional[jax.Array] = None,
    engine: str = "doubling",
) -> Tuple[jax.Array, jax.Array]:
    """BFS over the undirected edge list from `root`.

    Args:
        u, v: (L,) int32 endpoints.
        n: number of nodes (static).
        root: scalar int32 root node.
        edge_mask: optional (L,) bool — True edges participate (used to
            run BFS restricted to the spanning tree, and to mask padding
            edges in the batched pipeline).
        engine: "doubling" (default, O(log n) rounds on chain-like
            inputs) or "levels" (one round per BFS level). Bit-identical
            outputs; purely a performance knob.

    Returns:
        depth:  (n,) int32, INF for unreachable.
        parent: (n,) int32, -1 for root / unreachable.
    """
    if engine == "doubling":
        return bfs_doubling(u, v, n, root, edge_mask)
    if engine != "levels":
        raise ValueError(f"unknown BFS engine {engine!r}")
    return bfs_levels(u, v, n, root, edge_mask)


@functools.partial(jax.jit, static_argnames=("n",))
def bfs_levels(
    u: jax.Array,
    v: jax.Array,
    n: int,
    root: jax.Array,
    edge_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Level-synchronous BFS: one edge-parallel relaxation per level."""
    src = jnp.concatenate([u, v])
    dst = jnp.concatenate([v, u])
    if edge_mask is not None:
        emask = jnp.concatenate([edge_mask, edge_mask])
    else:
        emask = jnp.ones_like(src, dtype=bool)

    depth0 = jnp.full((n,), INF, dtype=jnp.int32).at[root].set(0)
    parent0 = jnp.full((n,), -1, dtype=jnp.int32)
    frontier0 = jnp.zeros((n,), dtype=bool).at[root].set(True)

    def cond(state):
        _, _, frontier, _ = state
        return jnp.any(frontier)

    def body(state):
        depth, parent, frontier, level = state
        active = frontier[src] & emask
        # candidate parent for each destination: smallest active source id
        cand = jnp.full((n,), INF, dtype=jnp.int32)
        cand = cand.at[dst].min(jnp.where(active, src, INF))
        newly = (cand != INF) & (depth == INF)
        parent = jnp.where(newly, cand, parent)
        depth = jnp.where(newly, level + 1, depth)
        return depth, parent, newly, level + 1

    depth, parent, _, _ = jax.lax.while_loop(
        cond, body, (depth0, parent0, frontier0, jnp.int32(0))
    )
    return depth, parent


@functools.partial(jax.jit, static_argnames=("n",))
def bfs_doubling(
    u: jax.Array,
    v: jax.Array,
    n: int,
    root: jax.Array,
    edge_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Hop-doubling BFS: Bellman–Ford relaxations + pointer doubling.

    State: tentative depths ``dist`` (INF = not yet bounded) plus three
    pointer families over the tentative-parent forest, each carrying a
    walk-length offset so a pull ``dist[v] <- dist[p[v]] + off[v]`` is
    always a valid upper bound (a real walk exists, or the offset has
    been clamped to n, which also upper-bounds every true depth):

      * two *static monotone chains* — every node points at its
        smallest-id and largest-id neighbour; squaring them each round
        makes the chains jump 2^k hops, which is what carries depth
        information across O(n)-diameter stretches in O(log n) rounds
        (the reach mechanism; on feeder inputs node ids follow the
        chain, so the two directions cover both sides of the root);
      * a *re-anchored climb* — each round the tentative-parent forest
        (every node points at its minimum-dist neighbour) is rebuilt
        from the current bounds and climbed with log n unrolled
        doubling steps. Where bounds carry a locally uniform error the
        chain's hop count telescopes to the exact bound difference, so
        whole regions snap to the exact depth the round after their
        chain first touches an exact node (the correction mechanism —
        this is what makes arbitrary-id inputs converge fast too).

    Every candidate ever written is ≥ the true depth (walk lengths, or
    the clamp n ≥ depth+1), so at the relaxation fixpoint — the loop
    exit — ``dist`` *equals* the true BFS depth: standard Bellman–Ford
    induction along shortest paths. Rounds are additionally bounded by
    the diameter (relaxation alone fixes level k by round k), so the
    engine never runs more rounds than level-sync; on chain-like inputs
    it runs O(log n). All values stay in [0, n] ∪ {INF}: int32-safe.

    Per-round cost is kept to ONE scatter: the relaxation minimum and
    the climb's re-anchor witness come out of a single scatter-min of
    the packed key dist[u]·(n+1) + u (dist is clamped to ≤ n, so the
    key fits int32 up to n = PACKED_KEY_MAX_N; beyond that the same
    pass runs unpacked as two scatter-mins). The climb is truncated to ~0.6·log n
    steps — correction jumps of 2^0.6·log ≫ the per-round reach growth,
    measured faster at every size with identical convergence.

    The parent is derived after the loop in one edge-parallel pass:
    parent[v] = smallest-id neighbour u with depth[u] == depth[v] - 1 —
    exactly the level-sync rule, evaluated on exact depths.
    """
    src = jnp.concatenate([u, v])
    dst = jnp.concatenate([v, u])
    if edge_mask is not None:
        emask = jnp.concatenate([edge_mask, edge_mask])
    else:
        emask = jnp.ones_like(src, dtype=bool)
    iota = jnp.arange(n, dtype=jnp.int32)
    nn = jnp.int32(n)
    log = _log2_ceil(n + 1)
    climb_len = max(2, (3 * log) // 5)
    packed = n <= PACKED_KEY_MAX_N  # packed_key_bound(n) fits int32
    base = jnp.int32(n + 1)
    KINF = jnp.iinfo(jnp.int32).max

    # static monotone chains: smallest- / largest-id neighbour
    lo_nbr = jnp.full((n,), INF, jnp.int32).at[dst].min(
        jnp.where(emask, src, INF)
    )
    hi_nbr = jnp.full((n,), -1, jnp.int32).at[dst].max(
        jnp.where(emask, src, -1)
    )
    has_lo = lo_nbr != INF
    fallback = jnp.where(has_lo, lo_nbr, iota)
    pl0 = fallback
    ol0 = jnp.where(pl0 != iota, 1, 0).astype(jnp.int32)
    pr0 = jnp.where(hi_nbr >= 0, hi_nbr, iota)
    or0 = jnp.where(pr0 != iota, 1, 0).astype(jnp.int32)
    dist0 = jnp.full((n,), INF, jnp.int32).at[root].set(0)

    def pull(dist, p, o):
        c = jnp.where(dist[p] < INF, jnp.minimum(dist[p] + o, nn), INF)
        return jnp.minimum(dist, c)

    def relax_witness(dist):
        """(min-neighbour dist, smallest-id argmin) in one scatter."""
        if packed:
            key = jnp.where(emask & (dist[src] < INF),
                            dist[src] * base + src, KINF)
            kmin = jnp.full((n,), KINF, jnp.int32).at[dst].min(key)
            has = kmin < KINF
            mnb = jnp.where(has, kmin // base, INF)
            wit = jnp.where(has, kmin % base, n)
            return mnb, wit
        mnb = jnp.full((n,), INF, jnp.int32).at[dst].min(
            jnp.where(emask, dist[src], INF))
        wit = jnp.full((n,), n, jnp.int32).at[dst].min(
            jnp.where(emask & (dist[src] == mnb[dst]), src, n))
        wit = jnp.where(mnb < INF, wit, n)
        return mnb, wit

    def body(state):
        dist, pl, ol, pr, orr, _ = state
        d_in = dist
        # edge-parallel relaxation + climb re-anchor, one scatter-min
        mnb, wit = relax_witness(dist)
        dist = jnp.minimum(dist, jnp.where(mnb < INF,
                                           jnp.minimum(mnb + 1, nn), INF))
        # static chains: pull, then square the pointers
        dist = pull(dist, pl, ol)
        dist = pull(dist, pr, orr)
        ol = jnp.minimum(ol + ol[pl], nn)
        pl = pl[pl]
        orr = jnp.minimum(orr + orr[pr], nn)
        pr = pr[pr]
        # re-anchored climb over the tentative-parent forest
        ptc = jnp.where(wit < n, wit, fallback)
        ptc = jnp.where(iota == root, root, ptc)
        jmp = ptc
        joff = jnp.where(jmp != iota, 1, 0).astype(jnp.int32)
        for _ in range(climb_len):
            dist = pull(dist, jmp, joff)
            joff = jnp.minimum(joff + joff[jmp], nn)
            jmp = jmp[jmp]
        return dist, pl, ol, pr, orr, jnp.any(dist != d_in)

    def cond(state):
        return state[-1]

    dist, *_ = jax.lax.while_loop(
        cond, body, (dist0, pl0, ol0, pr0, or0, jnp.bool_(True))
    )

    # one edge-parallel pass: smallest-id neighbour one level up
    prev = emask & (dist[src] < INF) & (dist[dst] < INF) \
        & (dist[src] + 1 == dist[dst])
    cand = jnp.full((n,), INF, jnp.int32).at[dst].min(
        jnp.where(prev, src, INF)
    )
    parent = jnp.where((dist > 0) & (dist < INF) & (cand < INF), cand, -1)
    return dist, parent.astype(jnp.int32)


def _euler_tables(tour: jax.Array, T: jax.Array, depth: jax.Array,
                  n: int):
    """`lca.tables_from_tour` — the ONE definition of the table layout
    `lca_euler` queries, shared with `build_euler` (local import only to
    keep bfs.py importable without the lca module at module load)."""
    from repro.core.lca import tables_from_tour

    return tables_from_tour(tour, T, depth, n)


@functools.partial(jax.jit, static_argnames=("n", "with_euler"))
def root_tree_euler(
    u: jax.Array,
    v: jax.Array,
    n: int,
    root: jax.Array,
    tree_mask: jax.Array,
    with_euler: bool = True,
):
    """Root the spanning tree at `root` in O(log n) rounds — no BFS.

    Returns (depth, parent, euler) with (depth, parent) bit-identical
    to ``bfs(u, v, n, root, edge_mask=tree_mask)``: in a tree the depth
    is unique and each non-root node has exactly one neighbour one
    level up, so the smallest-id parent rule is vacuous — rooting IS
    the answer. The construction materialises the Euler tour straight
    from the undirected edge list (``lca.build_euler`` starts from
    parent pointers, which is exactly what we don't have yet):

      1. arcs: edge i yields ``i`` (u→v) and ``L+i`` (v→u); sort arcs
         by (tail, head) so each node's out-arcs form one sorted block
         (one u32 radix key when ids fit 16 bits, the u64 pair sort
         otherwise);
      2. successor pointers: succ(x→y) = the arc after (y→x) in y's
         block, circular — the classic Euler-circuit rule; the arc that
         would close the circuit back to the root's first out-arc is
         made a self-loop terminator instead;
      3. pointer-doubling list ranking over the 2L arc slots gives each
         tour arc its rank (and membership: only arcs in the root's
         component reach the terminator — a padded or disconnected
         forest is toured exactly as far as level-sync BFS would walk);
      4. depth = prefix sum of +1 (down-arc) / −1 (up-arc) over the
         ranked tour; a down arc (x→y) is one with rank < its reversal
         and assigns parent[y] = x.

    with_euler=True additionally turns the already-ranked tour into the
    `lca.EulerLCA` sparse tables (`_euler_tables`) — the pipeline's
    O(1)-LCA backend without a second tour construction. (The tour
    enters each node's children after-the-parent circularly instead of
    build_euler's from-the-smallest; both are valid Euler tours, and
    every LCA/distance query answers identically — the range minimum
    between two first occurrences is the unique LCA node either way.)

    Everything is sort/gather/scatter with static shapes — vmap-safe
    for the padded batched pipeline (tree_mask already excludes padding
    edges, so padded slots sort to the invalid tail).
    """
    from repro.core.sort import radix_argsort_u32, radix_argsort_u64pair

    L = u.shape[0]
    depth0 = jnp.full((n,), INF, jnp.int32).at[root].set(0)
    parent0 = jnp.full((n,), -1, jnp.int32)
    if L == 0:
        euler = None
        if with_euler:
            P = 2 * n - 1
            tour0 = jnp.zeros((P,), jnp.int32).at[0].set(root)
            euler = _euler_tables(tour0, jnp.int32(0), depth0, n)
        return depth0, parent0, euler
    A = 2 * L
    aiota = jnp.arange(A, dtype=jnp.int32)
    tail = jnp.concatenate([u, v]).astype(jnp.int32)
    head = jnp.concatenate([v, u]).astype(jnp.int32)
    valid = jnp.concatenate([tree_mask, tree_mask])
    rev = jnp.where(aiota < L, aiota + L, aiota - L)

    # -- 1. sorted out-arc blocks ---------------------------------------
    if n <= EULER_PACK_MAX_N:  # (tail, head) packs into one u32 key
        key = (tail.astype(jnp.uint32) << 16) | head.astype(jnp.uint32)
        S = radix_argsort_u32(jnp.where(valid, key,
                                        jnp.uint32(0xFFFFFFFF)))
    else:
        hi = jnp.where(valid, tail.astype(jnp.uint32),
                       jnp.uint32(0xFFFFFFFF))
        S = radix_argsort_u64pair(hi, head.astype(jnp.uint32))
    pos = jnp.zeros((A,), jnp.int32).at[S].set(aiota)
    st = jnp.where(valid[S], tail[S], -1)
    is_first = valid[S] & ((aiota == 0) | (st != jnp.roll(st, 1)))
    is_last = valid[S] & ((aiota == A - 1) | (st != jnp.roll(st, -1)))
    stc = jnp.clip(st, 0, n - 1)
    start_pos = jnp.zeros((n,), jnp.int32).at[
        jnp.where(is_first, stc, n)].set(aiota, mode="drop")
    first_arc = jnp.full((n,), -1, jnp.int32).at[
        jnp.where(is_first, stc, n)].set(S, mode="drop")

    # -- 2. successor pointers + terminator -----------------------------
    succ_pos = jnp.where(is_last, start_pos[stc],
                         jnp.minimum(aiota + 1, A - 1))
    succ = jnp.where(valid, S[succ_pos[pos[rev]]], aiota)
    s0 = first_arc[root]          # root's first out-arc (-1: bare root)
    has_tour = s0 >= 0
    is_term = valid & (succ == s0) & has_tour
    term = jnp.argmax(is_term).astype(jnp.int32)
    succ = jnp.where(is_term, aiota, succ)

    # -- 3. list ranking by pointer doubling ----------------------------
    d = jnp.where(succ != aiota, 1, 0).astype(jnp.int32)
    nxt = succ
    for _ in range(_log2_ceil(A) + 1):
        d = d + d[nxt]
        nxt = nxt[nxt]
    in_tour = has_tour & valid & (nxt == term)
    T = jnp.where(has_tour, d[jnp.maximum(s0, 0)] + 1, 0)
    rank = T - 1 - d  # rank(s0) == 0, rank(term) == T - 1

    # -- 4. depth prefix sum + parents ----------------------------------
    down = in_tour & (d > d[rev])
    seq = jnp.zeros((A,), jnp.int32).at[
        jnp.where(in_tour, rank, A)].set(
        jnp.where(down, 1, -1), mode="drop")
    csum = jnp.cumsum(seq)
    hsafe = jnp.where(down, head, n)
    parent = parent0.at[hsafe].set(tail, mode="drop")
    depth = depth0.at[hsafe].set(
        csum[jnp.clip(rank, 0, A - 1)], mode="drop")
    euler = None
    if with_euler:
        # arc of rank r contributes its head at tour position r + 1
        P = 2 * n - 1
        wpos = jnp.where(in_tour, jnp.minimum(rank + 1, P), P)
        tour = (jnp.zeros((P,), jnp.int32).at[0].set(root)
                .at[wpos].set(head, mode="drop"))
        euler = _euler_tables(tour, T, depth, n)
    return depth, parent, euler


@functools.partial(jax.jit, static_argnames=("n",))
def root_tree(
    u: jax.Array,
    v: jax.Array,
    n: int,
    root: jax.Array,
    tree_mask: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """`root_tree_euler` without the LCA tables: (depth, parent) only."""
    depth, parent, _ = root_tree_euler(u, v, n, root, tree_mask,
                                       with_euler=False)
    return depth, parent


@functools.partial(jax.jit, static_argnames=("n",))
def degrees(
    u: jax.Array, v: jax.Array, n: int, edge_valid: Optional[jax.Array] = None
) -> jax.Array:
    one = (
        jnp.ones_like(u)
        if edge_valid is None
        else edge_valid.astype(jnp.int32)
    )
    deg = jnp.zeros((n,), dtype=jnp.int32)
    deg = deg.at[u].add(one)
    deg = deg.at[v].add(one)
    return deg


@functools.partial(jax.jit, static_argnames=("n",))
def select_root(
    u: jax.Array, v: jax.Array, n: int, edge_valid: Optional[jax.Array] = None
) -> jax.Array:
    """Max-degree node, ties -> smallest id (matches Graph.root()).

    edge_valid: optional (L,) padding mask — padding edges contribute no
    degree, so padded nodes (degree 0) can never win against any node of
    the real, connected graph.
    """
    deg = degrees(u, v, n, edge_valid)
    return jnp.argmax(deg).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n",))
def effective_weights(
    u: jax.Array,
    v: jax.Array,
    w: jax.Array,
    depth: jax.Array,
    n: int,
    edge_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """feGRASS-style depth-scaled effective weight (the EFF subroutine).

    eff(e) = w(e) * (depth[u] + depth[v] + 1). Any fixed monotone
    combination works for the pipeline; this one is shared with the
    oracle. Unreachable (INF) depths are clamped to 0 first — on a
    disconnected input the raw INT32_MAX would cast to float32 ≈ 2.1e9
    and poison every weight it touches (`finite_depth`; the numpy
    mirror applies the same guard).

    edge_valid: optional (L,) padding mask — padding slots are zeroed
    so their (garbage-endpoint) gathers can never leak a value out.
    Downstream consumers mask again (the criticality sort forces
    invalid keys to -inf), so threading the mask here changes no real
    slot.
    """
    d = finite_depth(depth).astype(jnp.float32)
    eff = w * (d[u] + d[v] + 1.0)
    if edge_valid is not None:
        eff = jnp.where(edge_valid, eff, 0.0)
    return eff
