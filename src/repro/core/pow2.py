"""Shared power-of-two helpers (host-side shape/bucket arithmetic).

Every layer that pads or buckets shapes needs the same two integers:
`next_pow2` for pad targets (serving buckets, batch axes, buffer caps)
and `log2_ceil` for table depths (binary-lifting levels). They used to
be re-implemented per module; this is the single home (tests/test_pow2.py).
"""
from __future__ import annotations


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (x >= 1)."""
    p = 1
    while p < x:
        p <<= 1
    return p


def log2_ceil(n: int) -> int:
    """Smallest k >= 1 with 2**k >= n.

    The floor of 1 matters: binary-lifting tables always carry at least
    one level so the climb loops are well-formed for trivial trees.
    """
    k = 1
    while (1 << k) < n:
        k += 1
    return k


def auto_chunk(m: int, lo: int = 8, hi: int = 64) -> int:
    """Power-of-two block size ~ sqrt(m), clamped to [lo, hi].

    The chunked schedulers (phase-1 marking, recovery replay) pay one
    batched LCA per block of C slots plus a C-step arithmetic inner
    scan, so per-block cost grows ~C^2 while the step count shrinks as
    m/C; C ~ sqrt(m) balances the two, and the pow2 grid keeps the
    number of distinct compiled shapes small across serving buckets.
    """
    c = lo
    while c < hi and c * c < m:
        c <<= 1
    return c
