"""Shared power-of-two helpers (host-side shape/bucket arithmetic).

Every layer that pads or buckets shapes needs the same two integers:
`next_pow2` for pad targets (serving buckets, batch axes, buffer caps)
and `log2_ceil` for table depths (binary-lifting levels). They used to
be re-implemented per module; this is the single home (tests/test_pow2.py).
"""
from __future__ import annotations

# Largest power of two representable as a (positive) int32 — the hard
# ceiling for every pow2 pad target / bucket size that ends up as an
# int32 shape constant or index on device. Exported for the static
# range checker (repro.analysis.ranges); `next_pow2` enforces it.
MAX_POW2_INT32 = 1 << 30


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (x >= 1), int32-safe.

    Raises for x > MAX_POW2_INT32: the next bucket would overflow the
    int32 shape/index arithmetic every consumer of these pad targets
    performs on device.
    """
    if x > MAX_POW2_INT32:
        raise ValueError(
            f"pow2 bucket for {x} exceeds MAX_POW2_INT32={MAX_POW2_INT32}")
    p = 1
    while p < x:
        p <<= 1
    return p


def log2_ceil(n: int) -> int:
    """Smallest k >= 1 with 2**k >= n.

    The floor of 1 matters: binary-lifting tables always carry at least
    one level so the climb loops are well-formed for trivial trees.
    """
    k = 1
    while (1 << k) < n:
        k += 1
    return k


def auto_chunk(m: int, lo: int = 8, hi: int = 64) -> int:
    """Power-of-two block size ~ sqrt(m), clamped to [lo, hi].

    The chunked schedulers (phase-1 marking, recovery replay) pay one
    batched LCA per block of C slots plus a C-step arithmetic inner
    scan, so per-block cost grows ~C^2 while the step count shrinks as
    m/C; C ~ sqrt(m) balances the two, and the pow2 grid keeps the
    number of distinct compiled shapes small across serving buckets.
    """
    c = lo
    while c < hi and c * c < m:
        c <<= 1
    return c
