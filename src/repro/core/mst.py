"""Maximum spanning tree via Borůvka in JAX (the MST subroutine).

The baseline program computes the spanning tree sequentially (Kruskal
over sorted effective weights). Borůvka is the parallel-native choice:
every round each component picks its best incident inter-component edge
with one segmented min — a pure scatter-min over the edge list — then
components contract by pointer jumping. O(log N) rounds of O(L) work,
all fully vectorised (the TPU adaptation of sequential union-find, whose
pointer chasing does not vectorise).

Edges are compared by a precomputed *rank* (position in the
(eff-weight desc, edge-id asc) total order, from `sort.sort_f32_desc_stable`).
Because the order is total, the maximum spanning tree is unique, and
Borůvka and Kruskal provably return the same edge set — the python oracle
uses Kruskal, tests assert equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

INF = jnp.iinfo(jnp.int32).max


@functools.partial(jax.jit, static_argnames=("n",))
def boruvka_mst(
    u: jax.Array,
    v: jax.Array,
    rank: jax.Array,
    n: int,
    edge_valid: jax.Array | None = None,
) -> jax.Array:
    """Returns (L,) bool mask of spanning-tree edges.

    rank: (L,) int32, a total order (0 = best edge). The tree minimises
    total rank, i.e. maximises effective weight under our ordering.

    edge_valid: optional (L,) bool padding mask (batched pipeline) —
    padding edges are never inter-component candidates, so they can never
    enter the tree, and the termination test ignores them.
    """
    if edge_valid is None:
        edge_valid = jnp.ones_like(u, dtype=bool)

    def pointer_jump(ptr):
        def cond(p):
            return jnp.any(p[p] != p)

        def body(p):
            return p[p]

        return jax.lax.while_loop(cond, body, ptr)

    def round_cond(state):
        comp, _ = state
        return jnp.any((comp[u] != comp[v]) & edge_valid)

    def round_body(state):
        comp, tree_mask = state
        cu, cv = comp[u], comp[v]
        inter = (cu != cv) & edge_valid
        key = jnp.where(inter, rank, INF)
        best = jnp.full((n,), INF, dtype=jnp.int32)
        best = best.at[cu].min(key)
        best = best.at[cv].min(key)
        chosen = inter & ((rank == best[cu]) | (rank == best[cv]))
        tree_mask = tree_mask | chosen
        # hook: each component points to the smallest neighbouring component
        ptr = jnp.arange(n, dtype=jnp.int32)
        ptr = ptr.at[cu].min(jnp.where(chosen, cv, INF))
        ptr = ptr.at[cv].min(jnp.where(chosen, cu, INF))
        ptr = jnp.minimum(ptr, jnp.arange(n, dtype=jnp.int32))
        # break mutual 2-cycles deterministically (smaller id wins)
        ids = jnp.arange(n, dtype=jnp.int32)
        mutual = (ptr[ptr] == ids) & (ptr != ids)
        ptr = jnp.where(mutual & (ids < ptr), ids, ptr)
        ptr = pointer_jump(ptr)
        return ptr[comp], tree_mask

    comp0 = jnp.arange(n, dtype=jnp.int32)
    mask0 = jnp.zeros_like(u, dtype=bool)
    _, tree_mask = jax.lax.while_loop(round_cond, round_body, (comp0, mask0))
    return tree_mask


def kruskal_mst_numpy(u, v, rank, n):
    """Host Kruskal on the same total order — oracle / test reference."""
    import numpy as np

    order = np.argsort(rank, kind="stable")
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    mask = np.zeros(len(u), dtype=bool)
    cnt = 0
    for e in order:
        a, b = find(int(u[e])), find(int(v[e]))
        if a != b:
            parent[max(a, b)] = min(a, b)
            mask[e] = True
            cnt += 1
            if cnt == n - 1:
                break
    return mask
