"""Beyond-paper integration: LGRASS as a long-context attention
sparsifier.

Long-context attention over S tokens is a dense graph over S/B blocks.
We build a weighted block graph (sliding-window locality edges + content
similarity chords from mean-pooled block embeddings), run the *exact same*
LGRASS pipeline the power-grid task uses, and keep the sparsifier's edges
as the block-sparse attention mask. The spanning tree guarantees every
block can attend along a connected backbone (information can flow
anywhere), and the spectrally-critical chords keep the long-range links
that matter most — the graph-spectral analogue of landmark/global tokens.

This makes the paper's contribution a first-class *framework feature*
(an attention-mask planner in the data/serving plane), not just a
standalone solver.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.core.sparsify import lgrass_sparsify


@dataclasses.dataclass
class BlockMaskPlan:
    n_blocks: int
    mask: np.ndarray        # (n_blocks, n_blocks) bool, causal, incl. diag
    kept_edges: int
    total_edges: int


def build_block_graph(block_feats: np.ndarray, window: int = 2,
                      n_chords_per_block: int = 4,
                      seed: int = 0) -> Graph:
    """block_feats: (NB, d) mean-pooled block embeddings (host numpy)."""
    nb, d = block_feats.shape
    f = block_feats / (np.linalg.norm(block_feats, axis=1, keepdims=True)
                       + 1e-6)
    sim = f @ f.T  # (NB, NB) cosine
    edges = {}
    # locality edges (always candidates, strongly weighted)
    for i in range(nb):
        for j in range(max(0, i - window), i):
            edges[(j, i)] = 2.0 + max(sim[i, j], 0.0)
    # content chords: top-k similar earlier blocks
    for i in range(nb):
        if i <= window:
            continue
        cand = sim[i, : max(i - window, 0)]
        top = np.argsort(-cand)[:n_chords_per_block]
        for j in top:
            key = (min(int(j), i), max(int(j), i))
            edges.setdefault(key, 1.0 + max(float(cand[j]), 0.0))
    u = np.array([a for a, _ in edges], np.int32)
    v = np.array([b for _, b in edges], np.int32)
    w = np.array(list(edges.values()), np.float32)
    g = Graph(n=nb, u=u, v=v, w=w)
    g.validate()
    return g


def plan_block_mask(block_feats: np.ndarray, keep_frac: float = 0.15,
                    window: int = 2) -> BlockMaskPlan:
    """LGRASS-sparsified causal block mask."""
    g = build_block_graph(block_feats, window=window)
    budget = max(1, int(keep_frac * g.n))
    res = lgrass_sparsify(g, budget=budget, parallel=False)
    nb = g.n
    mask = np.zeros((nb, nb), bool)
    np.fill_diagonal(mask, True)
    for eid in np.where(res.edge_mask)[0]:
        a, b = int(g.u[eid]), int(g.v[eid])
        lo, hi = min(a, b), max(a, b)
        mask[hi, lo] = True  # causal: later block attends to earlier
    return BlockMaskPlan(n_blocks=nb, mask=mask,
                         kept_edges=int(res.edge_mask.sum()),
                         total_edges=g.m)


def block_sparse_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           mask_blocks: jax.Array,
                           block: int) -> jax.Array:
    """Exact attention restricted to allowed (q-block, k-block) pairs.

    q/k/v: (B, S, H, D); mask_blocks: (S/block, S/block) bool (causal).
    Reference implementation (dense with mask); the Pallas flash kernel
    consumes the same mask per (qi, ki) tile on real hardware by skipping
    masked tiles.
    """
    b, s, h, d = q.shape
    nb = s // block
    scale = d ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    tok_mask = jnp.repeat(jnp.repeat(mask_blocks, block, 0), block, 1)
    causal = jnp.tril(jnp.ones((s, s), bool))
    full = tok_mask & causal
    scores = jnp.where(full[None, None], scores, -1e9)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
