"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
(multi-head latent attention, hf:openbmb/MiniCPM3-4B): q_lora=768,
kv_lora=256, qk_nope=64, qk_rope=32, v_head=64. The KV cache stores the
compressed latent + rope key only."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
)
