"""The paper's own workload: LGRASS graph sparsification cases.

Each "shape" is a graph size; the dry-run lowers the distributed phase-1
(repro.core.distributed) over the production mesh for each case.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GraphCase:
    name: str
    n_nodes: int
    n_edges: int


CASES = {
    "case1_4k": GraphCase("case1_4k", 4_096, 13_056),
    "case2_7k": GraphCase("case2_7k", 7_056, 22_344),
    "case3_16k": GraphCase("case3_16k", 16_129, 51_200),
    "rand_1m": GraphCase("rand_1m", 1_048_576, 3_145_728),
}
