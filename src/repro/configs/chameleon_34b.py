"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.

Early-fusion VLM (arXiv:2405.09818): image VQ tokens share the text vocab,
so the backbone is a plain decoder LM; the VQ tokenizer frontend is a stub
(`input_specs` feeds token ids that already include image tokens).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    attn_type="gqa",
    frontend="vlm",
)
