"""mamba2-370m [ssm]: 48L d_model=1024, attn-free, ssm_state=128.

SSD (state-space duality) per arXiv:2405.21060. d_inner = 2*d_model = 2048,
headdim 64 -> 32 SSD heads. No attention, no FFN (Mamba2 blocks only).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attn_type="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=256,
    ssm_ngroups=1,
    tie_embeddings=True,
)
