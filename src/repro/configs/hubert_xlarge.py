"""hubert-xlarge [audio]: 48L d_model=1280 16H (MHA) d_ff=5120 vocab=504 —
encoder-only masked prediction over 504 cluster classes (arXiv:2106.07447).

The conv waveform frontend is a STUB: input_specs() provides precomputed
frame features (B, T, 512) which a linear projection lifts to d_model.
Positional information uses RoPE (adaptation: the original conv-positional
encoder is frontend-side; noted in DESIGN.md). No decode shapes (encoder).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    attn_type="gqa",
    is_encoder=True,
    act="gelu",
    frontend="audio",
    feat_dim=512,
)
