"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504,
ssm_state=16 — parallel attention + mamba heads per block (arXiv:2411.13676).

Most layers use sliding-window attention (w=1024); layers {0, 15, 31} stay
global — this is what makes long_500k decode sub-quadratic. Simplification
vs the paper: no learnable meta tokens (noted in DESIGN.md).
Heterogeneous per-layer caches force the unrolled layout.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_type="gqa",
    sliding_window=1024,
    global_layers=(0, 15, 31),
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=50,
    ssm_chunk=128,
    layout="unroll",
)
