"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 (fine-grained, per-expert d_ff=512).

NOTE: the assignment's shape line says "MoE 40e top-8" while its prose
says "32 experts top-8"; we follow the structured shape line (40e).
[hf:ibm-granite/granite-3.0-1b-a400m-base family]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    attn_type="gqa",
    n_experts=40,
    moe_top_k=8,
)
