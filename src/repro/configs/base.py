"""Architecture configs: the 10 assigned LM-family architectures + LGRASS.

Every config is exact per the assignment. `padded_for_mesh` derives the
production variant with head/vocab/expert padding to the tensor-parallel
axis (16) — padding is zero-init extra capacity, recorded in DESIGN.md
§Hardware-adaptation; smoke tests instantiate the *reduced* unpadded
family to keep CPU cost tiny while exercising identical code paths.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    attn_type: str = "gqa"           # gqa | mla | none
    is_encoder: bool = False
    act: str = "swiglu"              # swiglu | gelu
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    global_layers: Tuple[int, ...] = ()
    # MLA (multi-head latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    ssm_ngroups: int = 1
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # modality frontend stub
    frontend: Optional[str] = None   # audio | vlm | None
    feat_dim: int = 0
    # numerics / misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: bool = True
    layout: str = "scan"             # scan | unroll (hybrid uses unroll)
    # book-keeping for padding (0 = not padded)
    real_n_heads: int = 0
    real_n_kv_heads: int = 0
    real_vocab_size: int = 0
    real_n_experts: int = 0

    # ---------------- derived ----------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def has_attention(self) -> bool:
        return self.attn_type != "none"

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic path: pure SSM, or hybrid with sliding windows."""
        if not self.has_attention:
            return True
        return self.sliding_window is not None

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    def n_params(self) -> int:
        """True parameter count (unpadded dims)."""
        d, v, f = self.d_model, self.vocab_size, self.d_ff
        hd = self.resolved_head_dim
        per_layer = 0
        if self.has_attention:
            if self.attn_type == "mla":
                per_layer += d * self.q_lora_rank
                per_layer += self.q_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.qk_rope_head_dim
                )
                per_layer += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                per_layer += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.v_head_dim
                )
                per_layer += self.n_heads * self.v_head_dim * d
                per_layer += self.q_lora_rank + self.kv_lora_rank
            else:
                per_layer += d * self.n_heads * hd
                per_layer += 2 * d * self.n_kv_heads * hd
                per_layer += self.n_heads * hd * d
        if self.has_ssm:
            di = self.d_inner
            conv_dim = di + 2 * self.ssm_ngroups * self.ssm_state
            per_layer += d * (2 * di + 2 * self.ssm_ngroups * self.ssm_state
                              + self.ssm_nheads)
            per_layer += self.ssm_conv * conv_dim
            per_layer += 3 * self.ssm_nheads + di  # A_log, D, dt_bias, norm
            per_layer += di * d
        if self.is_moe:
            per_layer += d * self.n_experts
            nmat = 3 if self.act == "swiglu" else 2
            per_layer += self.n_experts * nmat * d * f
        elif f > 0:
            nmat = 3 if self.act == "swiglu" else 2
            per_layer += nmat * d * f
        per_layer += 2 * d  # norms
        total = self.n_layers * per_layer + v * d + 2 * d
        if not self.tie_embeddings:
            total += v * d
        if self.frontend == "audio":
            total += self.feat_dim * d
        return total

    # ---------------- variants ----------------
    def padded_for_mesh(self, tp: int) -> "ArchConfig":
        """Pad heads / kv heads / vocab / experts for a `tp`-way model axis."""
        ch: Dict = {}
        nh = self.n_heads
        nkv = self.n_kv_heads
        if self.has_attention and nh % tp != 0:
            new_h = _round_up(nh, tp)
            ch["n_heads"] = new_h
            ch["real_n_heads"] = nh
            if self.attn_type == "gqa" and nkv > 0:
                # smallest kv' >= kv that divides the padded head count,
                # so GQA grouping stays integral after padding
                new_kv = next(k for k in range(nkv, new_h + 1)
                              if new_h % k == 0)
                if new_kv != nkv:
                    ch["n_kv_heads"] = new_kv
                    ch["real_n_kv_heads"] = nkv
        if self.vocab_size % tp != 0:
            ch["vocab_size"] = _round_up(self.vocab_size, tp)
            ch["real_vocab_size"] = self.vocab_size
        if self.is_moe and self.n_experts % tp != 0:
            ch["n_experts"] = _round_up(self.n_experts, tp)
            ch["real_n_experts"] = self.n_experts
        if not ch:
            return self
        return dataclasses.replace(self, **ch)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests (exact code paths)."""
        ch: Dict = dict(
            n_layers=2,
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            vocab_size=97,
            dtype="float32",
            remat=False,
        )
        if self.has_attention:
            if self.attn_type == "mla":
                ch.update(n_heads=4, q_lora_rank=24, kv_lora_rank=16,
                          qk_nope_head_dim=8, qk_rope_head_dim=4, v_head_dim=8)
            else:
                group = max(1, self.n_heads // max(self.n_kv_heads, 1))
                ch.update(n_heads=4, n_kv_heads=max(1, 4 // group),
                          head_dim=16)
        if self.has_ssm:
            ch.update(ssm_state=8, ssm_headdim=16, ssm_chunk=16, ssm_conv=4)
        if self.is_moe:
            # cf=8: no capacity drops, so prefill+decode == full forward
            # exactly (drop policies are exercised in test_moe.py)
            ch.update(n_experts=4, moe_top_k=min(2, self.moe_top_k),
                      capacity_factor=8.0)
        if self.sliding_window:
            ch.update(sliding_window=16, global_layers=(0,))
        if self.frontend:
            ch.update(feat_dim=32)
        ch.update(real_n_heads=0, real_n_kv_heads=0, real_vocab_size=0,
                  real_n_experts=0)
        return dataclasses.replace(self, **ch)


# ---------------- input shapes (assignment) ----------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    """Assignment rules: which (arch × shape) cells are skipped and why."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention arch: 500k decode needs sub-quadratic "
                "attention (see DESIGN.md)")
    return None
