"""Config registry: --arch <id> resolution."""
from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, cell_skip_reason

from repro.configs.mamba2_370m import CONFIG as _mamba2
from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.phi3_mini_3_8b import CONFIG as _phi3
from repro.configs.minicpm3_4b import CONFIG as _minicpm3
from repro.configs.internlm2_20b import CONFIG as _internlm2
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.granite_moe_3b import CONFIG as _granite

ARCHS = {c.name: c for c in [
    _mamba2, _chameleon, _hymba, _starcoder2, _phi3,
    _minicpm3, _internlm2, _hubert, _dbrx, _granite,
]}

def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCHS", "get_arch",
           "cell_skip_reason"]
