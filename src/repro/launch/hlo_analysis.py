"""HLO-text cost analyzer with correct while-loop trip-count scaling.

XLA's `compiled.cost_analysis()` counts a while body ONCE, which silently
undercounts scan-over-layers programs by ~n_layers. This module parses
the compiled (SPMD-partitioned, per-device) HLO text and computes, per
computation:

  * dot FLOPs          — 2 * prod(result dims) * prod(contracting dims),
                         operand shapes resolved via a per-computation
                         symbol table;
  * HBM traffic proxy  — operands read + result written for every
                         top-level op (fusion-internal ops excluded: they
                         live in registers/VMEM);
  * collective wire bytes — all-gather / all-reduce / reduce-scatter /
                         all-to-all / collective-permute with ring-model
                         multipliers;

then walks the call graph (while bodies × known_trip_count, fusions for
their internal dot FLOPs, calls/conditionals × 1) to exact entry totals.

This is the measurement instrument for §Roofline / §Perf: per-op counts
expose redundant all-gathers and remat recompute directly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[su]\d+|bf16|f16|f32|f64|c64|c128)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*{")
_TRIP_RE = re.compile(r'known_trip_count[\\\":{]+n[\\\":]+(\d+)')
_CALL_ATTR_RE = re.compile(
    r"(?:body|calls|to_apply|condition|branch_computations)="
    r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_WIRE_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_list_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _dims_of(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    rtype: str
    opcode: str
    rest: str


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    mem_bytes: float = 0.0        # fusion-aware HBM traffic model
    mem_bytes_upper: float = 0.0  # every top-level op (pessimistic)
    mem_bytes_dots: float = 0.0   # dot operands/results only (lower bound)
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    transfers: int = 0            # host/cross-device transfer ops
    calls: List[Tuple[str, float, bool]] = dataclasses.field(
        default_factory=list)  # (callee, multiplier, fusion_internal)


# ops whose operands/results hit HBM even after TPU fusion: matmuls,
# data-movement ops, fusion boundaries, collectives. Plain elementwise
# top-level ops are assumed fused away (the CPU backend fuses less than
# the TPU backend; counting them would overstate HBM traffic ~10x).
_HBM_OPS = {
    "dot", "convolution", "fusion", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "reduce", "reduce-window",
    "sort", "copy", "concatenate", "pad", "all-gather", "all-reduce",
    "reduce-scatter", "all-to-all", "collective-permute",
    "all-gather-start", "all-reduce-start", "cholesky", "triangular-solve",
    "rng",
}

# host↔device / cross-device data movement: each of these is a transfer
# the serving path must not contain outside its one dispatch boundary.
_TRANSFER_OPS = {
    "copy-start", "copy-done", "send", "send-done", "recv", "recv-done",
    "infeed", "outfeed",
}

_ALIAS_PAIR_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{[\d,\s]*\},\s*"
    r"(may-alias|must-alias)\)")


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _dot_flops(rest: str, rtype: str, symtab: Dict[str, str]) -> float:
    rd = _dims_of(rtype)
    if rd is None:
        return 0.0
    _, rdims = rd
    out = 1.0
    for d in rdims:
        out *= d
    m = re.search(r"lhs_contracting_dims={([\d,]*)}", rest)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) \
        else []
    ops = _OPERAND_RE.findall(rest.split("),")[0] + ")")
    k = 1.0
    if ops:
        lhs_type = symtab.get(ops[0])
        if lhs_type:
            ld = _dims_of(lhs_type)
            if ld:
                for c in cdims:
                    if c < len(ld[1]):
                        k *= ld[1][c]
    return 2.0 * out * k


def analyze(text: str) -> Dict:
    comps = _split_computations(text)
    costs: Dict[str, CompCost] = {}
    entry_name = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry_name = m.group(1)

    fusion_bodies = set()
    for cname, lines in comps.items():
        cc = CompCost()
        symtab: Dict[str, str] = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rtype, opcode, rest = m.groups()
            symtab[name] = rtype
            rbytes = _shape_list_bytes(rtype)
            # operand bytes via symbol table
            obytes = 0
            arg_part = rest.split(")")[0]
            for op in _OPERAND_RE.findall(arg_part):
                if op in symtab:
                    obytes += _shape_list_bytes(symtab[op])
            if opcode == "dot":
                cc.flops += _dot_flops(rest, rtype, symtab)
                cc.mem_bytes_dots += rbytes + obytes
            is_coll = None
            for ck in COLLECTIVES:
                if opcode == ck or opcode == ck + "-start":
                    is_coll = ck
                    break
            if is_coll:
                # reduce-scatter ships the (larger) operand; the rest
                # are sized by their result
                nbytes = (obytes or rbytes) if is_coll == "reduce-scatter" \
                    else rbytes
                wire = nbytes * _WIRE_MULT[is_coll]
                cc.coll_bytes += wire
                cc.coll_by_kind[is_coll] = (
                    cc.coll_by_kind.get(is_coll, 0.0) + wire)
                cc.coll_counts[is_coll] = cc.coll_counts.get(is_coll, 0) + 1
            if opcode in _TRANSFER_OPS:
                cc.transfers += 1
            if opcode not in ("parameter", "constant", "tuple",
                              "get-tuple-element", "bitcast", "while",
                              "conditional", "call"):
                cc.mem_bytes_upper += rbytes + obytes
                if opcode in _HBM_OPS:
                    cc.mem_bytes += rbytes + obytes
            # call graph edges
            if opcode == "while":
                trip = 1.0
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = float(tm.group(1))
                for attr in ("body", "condition"):
                    am = re.search(attr + r"=%?([\w.\-]+)", rest)
                    if am:
                        cc.calls.append((am.group(1), trip, False))
            elif opcode == "fusion":
                am = re.search(r"calls=%?([\w.\-]+)", rest)
                if am:
                    cc.calls.append((am.group(1), 1.0, True))
                    fusion_bodies.add(am.group(1))
            elif opcode in ("call", "conditional", "reduce", "scatter",
                            "sort", "map", "reduce-window", "all-reduce",
                            "reduce-scatter", "select-and-scatter",
                            "custom-call"):
                for am in re.finditer(
                        r"(?:to_apply|branch_computations|called_computations"
                        r")=[{]?%?([\w.\-, %]+)[}]?", rest):
                    for callee in re.findall(r"[\w.\-]+", am.group(1)):
                        if callee in comps:
                            cc.calls.append((callee, 1.0, False))
        costs[cname] = cc

    memo: Dict[Tuple[str, bool], Tuple] = {}

    def total(cname: str, fusion_ctx: bool):
        key = (cname, fusion_ctx)
        if key in memo:
            return memo[key]
        cc = costs.get(cname)
        if cc is None:
            return (0.0, 0.0, 0.0, 0.0, 0.0, {}, {}, 0)
        fl = cc.flops
        mb = 0.0 if fusion_ctx else cc.mem_bytes
        mu = 0.0 if fusion_ctx else cc.mem_bytes_upper
        md = cc.mem_bytes_dots
        cb = cc.coll_bytes
        kinds = dict(cc.coll_by_kind)
        counts = dict(cc.coll_counts)
        tr = cc.transfers
        memo[key] = (fl, mb, mu, md, cb, kinds, counts, tr)  # cycle guard
        for callee, mult, as_fusion in cc.calls:
            f2, m2, u2, d2, c2, k2, n2, t2 = total(callee,
                                                   fusion_ctx or as_fusion)
            fl += f2 * mult
            mb += m2 * mult
            mu += u2 * mult
            md += d2 * mult
            cb += c2 * mult
            tr += int(t2 * mult)
            for k, v in k2.items():
                kinds[k] = kinds.get(k, 0.0) + v * mult
            for k, v in n2.items():
                counts[k] = counts.get(k, 0) + int(v * mult)
        memo[key] = (fl, mb, mu, md, cb, kinds, counts, tr)
        return memo[key]

    if entry_name is None:
        # fall back: the computation with the most instructions
        entry_name = max(comps, key=lambda c: len(comps[c])) if comps else ""
    fl, mb, mu, md, cb, kinds, counts, tr = total(entry_name, False)
    return dict(
        flops=fl,
        mem_bytes=mb,
        mem_bytes_upper=mu,
        mem_bytes_dots=md,
        collective_bytes=cb,
        collective_by_kind=kinds,
        collective_counts=counts,
        transfer_count=tr,
        output_alias=parse_output_alias(text),
        n_computations=len(comps),
        entry=entry_name,
    )


def parse_output_alias(text: str) -> List[Dict]:
    """Parse the module header's `input_output_alias` map: one entry
    per donated/aliased buffer, `{output_index}: (param, {...}, kind)`.
    An empty list on a donated program means donation silently failed
    (e.g. a shape mismatch made XLA drop the alias)."""
    start = text.find("input_output_alias={")
    if start < 0:
        return []
    i = start + len("input_output_alias=")
    depth = 0
    for j in range(i, min(len(text), i + 100_000)):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                break
    else:
        return []
    body = text[i + 1:j]
    out = []
    for oidx, param, kind in _ALIAS_PAIR_RE.findall(body):
        out.append(dict(
            output_index=[int(x) for x in oidx.replace(" ", "").split(",")
                          if x != ""],
            parameter=int(param),
            kind=kind,
        ))
    return out


def analyze_jitted(fn, *args, static_kwargs: Optional[dict] = None) -> Dict:
    """Lower + compile any jitted callable over `args` (arrays or
    `jax.ShapeDtypeStruct`s) and analyze the optimized HLO.

    Accepts either a `jax.jit`-wrapped function (lowered directly, so
    compile-time properties like `donate_argnums` survive — the
    `output_alias` report is only meaningful this way) or a plain
    callable (wrapped in a fresh jit). `static_kwargs` are forwarded at
    lowering time.

    This replaces the old copy-pasted per-program driver: every
    call site now funnels through one lowering path, and the report
    gains `transfer_count` (host/cross-device transfer ops — must be 0
    for a single-dispatch serving program) and `output_alias` (the
    donation aliases XLA actually honoured).
    """
    import jax

    static_kwargs = static_kwargs or {}
    if hasattr(fn, "lower"):
        lowered = fn.lower(*args, **static_kwargs)
    else:
        lowered = jax.jit(lambda *a: fn(*a, **static_kwargs)).lower(*args)
    compiled = lowered.compile()
    text = compiled.as_text()
    report = analyze(text)
    report["hlo_chars"] = len(text)
    return report
