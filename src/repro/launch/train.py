"""Training launcher.

    python -m repro.launch.train --arch phi3-mini-3.8b --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck [--resume]

Full-size archs train on the production mesh when real TPU devices are
present; on the CPU CI host use --reduced. The loop is the fault-tolerant
Trainer (checkpoint/restart, straggler monitor, deterministic data).
"""
import argparse
import dataclasses
import logging


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--compress", choices=["topk", "int8"], default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    from repro.configs import get_arch
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.ft.elastic import FaultConfig
    from repro.models.model import LM
    from repro.optim.optimizer import OptConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg)
    data = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        is_encoder=cfg.is_encoder, feat_dim=cfg.feat_dim))
    trainer = Trainer(
        model, data,
        OptConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                  total_steps=args.steps),
        TrainerConfig(total_steps=args.steps, log_every=args.log_every,
                      micro_batches=args.micro, compress=args.compress,
                      seed=args.seed),
        args.ckpt_dir,
        fault_cfg=FaultConfig(ckpt_every=args.ckpt_every),
    )
    out = trainer.run()
    h = out["history"]
    print(f"trained {len(h)} steps; loss {h[0]['loss']:.4f} -> "
          f"{h[-1]['loss']:.4f}; restarts={out['restarts']} "
          f"stragglers={out['stragglers']}")
    return out


if __name__ == "__main__":
    main()
