import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Everything below is ordinary code.

"""Multi-pod dry-run driver.

For every (architecture × input shape × mesh) cell this lowers the real
train/prefill/decode step with ShapeDtypeStruct stand-ins (no allocation),
compiles it for the production mesh, and records:

  * memory_analysis()       — proves the cell fits per-device HBM;
  * cost_analysis()         — HLO FLOPs / bytes for the roofline;
  * collective bytes        — parsed from the compiled per-device HLO
                              (all-gather / all-reduce / reduce-scatter /
                              all-to-all / collective-permute);
  * the three roofline terms and the MODEL_FLOPS/HLO_FLOPs ratio.

Artifacts land in experiments/artifacts/<arch>_<shape>_<mesh>.json and
are the inputs to benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --all [--mesh both] [--force]
    python -m repro.launch.dryrun --arch mamba2-370m --shape train_4k
    python -m repro.launch.dryrun --lgrass            # paper's own cells
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "experiments", "artifacts")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(pred|[su]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")

# approximate wire-bytes multiplier on the *result* bytes of each op
_WIRE_MULT = {
    "all-gather": 1.0,        # each device receives ~result bytes
    "all-reduce": 2.0,        # ring: reduce-scatter + all-gather
    "reduce-scatter": 1.0,    # sends ~operand ≈ result × N; use operands
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# per-cell microbatch count for train cells (activation-memory knob;
# chosen during §Perf iteration so every cell fits 16 GiB HBM)
DEFAULT_MICRO: Dict = {}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes per collective kind from partitioned HLO."""
    out = {k: 0.0 for k in _WIRE_MULT}
    counts = {k: 0 for k in _WIRE_MULT}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        if kind == "reduce-scatter" and len(shapes) > 1:
            nbytes = sum(_shape_bytes(d, s) for d, s in shapes[1:])
        else:
            nbytes = _shape_bytes(*shapes[0])
        out[kind] += nbytes * _WIRE_MULT[kind]
        counts[kind] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts}


def n_active_params(cfg) -> int:
    """Params touched per token (MoE: top-k of experts), excl. embeddings."""
    total = cfg.n_params()
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    body = total - emb
    if cfg.is_moe:
        nmat = 3 if cfg.act == "swiglu" else 2
        expert = cfg.n_layers * cfg.n_experts * nmat * cfg.d_model * cfg.d_ff
        body = body - expert + expert * cfg.moe_top_k / cfg.n_experts
    return int(body)


def model_flops(cfg, shape) -> float:
    na = n_active_params(cfg)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * na * tokens
    if shape.kind == "prefill":
        return 2.0 * na * tokens
    return 2.0 * na * shape.global_batch  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             outdir: str, force: bool = False,
             micro_batches: Optional[int] = None,
             tag_suffix: str = "",
             opts: tuple = ()) -> Optional[Dict]:
    """opts: beyond-paper optimisation toggles for §Perf reruns:
        'embed_dshard'           — lookup table d_model-sharded on 'model'
        'serve_params_resident'  — no FSDP axis on serve-path params
        'ssd_chunk128'           — SSD chunk 256 -> 128
    The default (no opts) is the paper-faithful baseline configuration.
    """
    import jax
    from repro.configs import SHAPES, cell_skip_reason, get_arch
    from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16,
                                   TP_SIZE, make_production_mesh)
    from repro.launch.specs import (batch_specs, cache_specs,
                                    decode_token_specs, params_specs,
                                    state_specs)
    from repro.models.model import LM
    from repro.models.sharding import use_mesh
    from repro.optim.optimizer import OptConfig
    from repro.serve.serve_step import make_decode_step, make_prefill_step
    from repro.train.train_step import make_train_step

    mesh_name = "multipod512" if multi_pod else "pod256"
    tag = f"{arch}_{shape_name}_{mesh_name}{tag_suffix}"
    path = os.path.join(outdir, f"{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg0 = get_arch(arch)
    shape = SHAPES[shape_name]
    if micro_batches is None:
        # 8 microbatches => per-device microbatch 1 (multi-pod) / 2
        # (single-pod): keeps saved residuals of 48L models inside HBM.
        default = 8 if SHAPES[shape_name].kind == "train" else 1
        micro_batches = DEFAULT_MICRO.get((arch, shape_name), default)
    skip = cell_skip_reason(cfg0, shape)
    if skip:
        rec = dict(cell=tag, arch=arch, shape=shape_name, mesh=mesh_name,
                   skipped=skip)
        os.makedirs(outdir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[dryrun] {tag}: SKIP ({skip})")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg0.padded_for_mesh(TP_SIZE)
    if "ssd_chunk128" in opts and cfg.ssm_chunk > 128:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, ssm_chunk=128)
    model = LM(cfg)
    serve_fsdp = "serve_params_resident" not in opts

    from repro.models import sharding as _sh
    saved_opts = set(_sh.OPTIMIZATIONS)
    _sh.OPTIMIZATIONS.update(opts)

    with use_mesh(mesh):
        if shape.kind == "train":
            sds_state, _ = state_specs(model, mesh)
            sds_batch = batch_specs(cfg, shape, mesh)
            gspecs = None
            if "grad_shard_accum" in opts:
                from repro.train.train_step import make_train_state_specs
                gspecs = make_train_state_specs(model)["params"]
            gdtype = "bfloat16" if "grad_bf16_sync" in opts else None
            step = make_train_step(model, OptConfig(),
                                   micro_batches=micro_batches,
                                   grad_shard_specs=gspecs,
                                   grad_sync_dtype=gdtype)
            lowered = jax.jit(step, donate_argnums=(0,)).lower(
                sds_state, sds_batch)
        elif shape.kind == "prefill":
            sds_params, _ = params_specs(model, mesh, fsdp=serve_fsdp)
            sds_batch = batch_specs(cfg, shape, mesh)
            caches = cache_specs(model, shape, mesh)
            if cfg.is_encoder:
                fn = lambda p, b: model.encode(p, b)
                lowered = jax.jit(fn).lower(sds_params, sds_batch)
            else:
                from repro.launch.mesh import batch_axes_for
                from jax.sharding import NamedSharding, PartitionSpec as P
                cache_sh = jax.tree.map(lambda s: s.sharding, caches)
                ba = batch_axes_for(shape.global_batch, mesh)
                logit_sh = NamedSharding(mesh, P(ba, "model"))
                fn = make_prefill_step(model)
                lowered = jax.jit(
                    fn, donate_argnums=(2,),
                    out_shardings=(logit_sh, cache_sh)).lower(
                    sds_params, sds_batch["tokens"], caches)
        else:  # decode
            from repro.launch.mesh import batch_axes_for
            from jax.sharding import NamedSharding, PartitionSpec as P
            sds_params, _ = params_specs(model, mesh, fsdp=serve_fsdp)
            caches = cache_specs(model, shape, mesh)
            cache_sh = jax.tree.map(lambda s: s.sharding, caches)
            ba = batch_axes_for(shape.global_batch, mesh)
            tok_sh = NamedSharding(mesh, P(ba, None))
            logit_sh = NamedSharding(mesh, P(ba, "model"))
            tok, pos = decode_token_specs(cfg, shape, mesh)
            fn = make_decode_step(model)
            lowered = jax.jit(
                fn, donate_argnums=(3,),
                out_shardings=(tok_sh, logit_sh, cache_sh)).lower(
                sds_params, tok, pos, caches)
        compiled = lowered.compile()
    _sh.OPTIMIZATIONS.clear()
    _sh.OPTIMIZATIONS.update(saved_opts)

    from repro.launch.hlo_analysis import analyze

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax wraps the dict in a list
        cost = cost[0] if cost else {}
    hlo = analyze(compiled.as_text())

    chips = 512 if multi_pod else 256
    # trip-count-correct per-device numbers from the HLO analyzer
    # (cost_analysis counts while bodies once — kept for reference only)
    flops = float(hlo["flops"])
    bytes_ = float(hlo["mem_bytes"])
    coll_bytes = float(hlo["collective_bytes"])
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_ / HBM_BW
    t_coll = coll_bytes / ICI_BW_PER_LINK
    mf = model_flops(cfg, shape)
    useful = mf / (flops * chips) if flops else 0.0
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]

    rec = dict(
        cell=tag, arch=arch, shape=shape_name, mesh=mesh_name,
        kind=shape.kind, chips=chips, opts=list(opts),
        micro_batches=micro_batches,
        compile_s=round(time.time() - t0, 1),
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=bytes_,
        hlo_bytes_upper_per_device=float(hlo["mem_bytes_upper"]),
        hlo_bytes_dots_per_device=float(hlo.get("mem_bytes_dots", 0.0)),
        collective_bytes_per_device=coll_bytes,
        collectives={**hlo["collective_by_kind"],
                     **{f"n_{k}": v for k, v in
                        hlo["collective_counts"].items()}},
        xla_cost_analysis=dict(
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0))),
        memory=dict(
            argument_bytes=int(mem.argument_size_in_bytes),
            output_bytes=int(mem.output_size_in_bytes),
            temp_bytes=int(mem.temp_size_in_bytes),
            code_bytes=int(mem.generated_code_size_in_bytes),
            # NOTE: the CPU (host) backend ignores buffer donation, so for
            # decode cells temp double-counts the donated cache (~2x). On
            # the TPU backend input caches alias outputs; subtract
            # output_bytes from temp for the HBM-fit estimate.
            hbm_estimate_bytes=int(mem.argument_size_in_bytes
                                   + max(mem.temp_size_in_bytes
                                         - mem.output_size_in_bytes, 0)),
        ),
        model_flops_global=mf,
        useful_flop_ratio=useful,
        t_compute_s=t_compute,
        t_memory_s=t_memory,
        t_collective_s=t_coll,
        dominant=dominant,
        roofline_fraction=(max(t_compute, 1e-30) /
                           max(t_compute, t_memory, t_coll)),
    )
    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"[dryrun] {tag}: ok in {rec['compile_s']}s | "
          f"flops/dev={flops:.3e} bytes/dev={bytes_:.3e} "
          f"coll/dev={coll_bytes:.3e} dominant={dominant} "
          f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB")
    return rec


def run_lgrass_cell(case_name: str, multi_pod: bool, outdir: str,
                    force: bool = False, k_cap: int = 32,
                    lift_levels: Optional[int] = None,
                    tag_suffix: str = "") -> Optional[Dict]:
    """Dry-run of the paper's own workload: distributed phase-1 marking.

    k_cap: accept-table width (correctness-neutral; recovery rechecks
    overflowed groups). lift_levels: depth-bounded lifting-table height —
    the host pipeline computes ceil(log2(max_depth+1)) from the tree BFS
    and slices the (LOG, n) table before dispatch; dry-run cells take it
    as a parameter (§Perf opt 'lift_bound').
    """
    import jax
    import jax.numpy as jnp
    from repro.configs.lgrass import CASES
    from repro.core.distributed import make_phase1_sharded
    from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16,
                                   make_production_mesh)

    mesh_name = "multipod512" if multi_pod else "pod256"
    tag = f"lgrass_{case_name}_{mesh_name}{tag_suffix}"
    path = os.path.join(outdir, f"{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    t0 = time.time()
    case = CASES[case_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    shard_axes = tuple(mesh.axis_names)
    n_shards = 1
    for a in shard_axes:
        n_shards *= mesh.shape[a]
    n, L = case.n_nodes, case.n_edges
    log = lift_levels or max(1, (n + 1).bit_length())
    lloc = (L + n_shards - 1) // n_shards
    total = lloc * n_shards

    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    shd = NamedSharding(mesh, P(shard_axes))
    sds = lambda shp, dt, sh: jax.ShapeDtypeStruct(shp, dt, sharding=sh)

    fn = make_phase1_sharded(mesh, shard_axes, k_cap=k_cap)
    lowered = fn.lower(
        sds((log, n), jnp.int32, rep),
        sds((n,), jnp.int32, rep),
        sds((total,), jnp.int32, shd),
        sds((total,), jnp.int32, shd),
        sds((total,), jnp.int32, shd),
        sds((total,), jnp.int32, shd),
        sds((total,), jnp.int32, shd),
        sds((total,), jnp.bool_, shd),
    )
    compiled = lowered.compile()
    from repro.launch.hlo_analysis import analyze
    mem = compiled.memory_analysis()
    hlo = analyze(compiled.as_text())
    chips = 512 if multi_pod else 256
    flops = float(hlo["flops"])
    bytes_ = float(hlo["mem_bytes"])
    coll_bytes = float(hlo["collective_bytes"])
    rec = dict(
        cell=tag, arch="lgrass", shape=case_name, mesh=mesh_name,
        kind="sparsify", chips=chips, k_cap=k_cap, lift_levels=log,
        compile_s=round(time.time() - t0, 1),
        hlo_flops_per_device=flops, hlo_bytes_per_device=bytes_,
        collective_bytes_per_device=coll_bytes,
        collectives=hlo["collective_by_kind"],
        memory=dict(
            argument_bytes=int(mem.argument_size_in_bytes),
            output_bytes=int(mem.output_size_in_bytes),
            temp_bytes=int(mem.temp_size_in_bytes),
            code_bytes=int(mem.generated_code_size_in_bytes)),
        t_compute_s=flops / PEAK_FLOPS_BF16,
        t_memory_s=bytes_ / HBM_BW,
        t_collective_s=coll_bytes / ICI_BW_PER_LINK,
        dominant="memory" if bytes_ / HBM_BW > coll_bytes / ICI_BW_PER_LINK
        else "collective",
    )
    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"[dryrun] {tag}: ok in {rec['compile_s']}s "
          f"bytes/dev={bytes_:.3e}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--lgrass", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args(argv)

    from repro.configs import ARCHS, SHAPES
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells = []
    if args.lgrass or args.all:
        from repro.configs.lgrass import CASES
        for c in CASES:
            for mp in meshes:
                cells.append(("lgrass", c, mp))
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                for mp in meshes:
                    cells.append((a, s, mp))
    elif args.arch:
        shapes = [args.shape] if args.shape else list(SHAPES)
        for s in shapes:
            for mp in meshes:
                cells.append((args.arch, s, mp))

    failures = []
    for a, s, mp in cells:
        try:
            if a == "lgrass":
                run_lgrass_cell(s, mp, args.out, args.force)
            else:
                run_cell(a, s, mp, args.out, args.force)
        except Exception as e:
            failures.append((a, s, mp, repr(e)))
            print(f"[dryrun] {a}_{s}_{'multi' if mp else 'single'}: "
                  f"FAIL {e!r}")
            traceback.print_exc()
    print(f"[dryrun] done; {len(failures)} failures")
    if failures:
        for f in failures:
            print("  FAIL:", f)
        sys.exit(1)


if __name__ == "__main__":
    main()
