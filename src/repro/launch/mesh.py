"""Production mesh construction (pure function — importing this module
never touches jax device state).

Target: TPU v5e pods. Single pod = 16×16 = 256 chips, axes
('data', 'model'); multi-pod = 2 pods = 512 chips, axes
('pod', 'data', 'model') where 'pod' is the DCN-connected pure-DP axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

TP_SIZE = 16  # 'model' axis extent on both meshes


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist, as a 1-D 'data' mesh (CPU tests, examples)."""
    n = len(jax.devices())
    return compat.make_mesh((n,), ("data",))


def batch_axes_for(global_batch: int, mesh: Mesh):
    """Largest prefix of ('pod','data') whose product divides the batch.

    decode long_500k has batch 1 — unsharded; train_4k batch 256 shards
    over pod×data = 32 ways.
    """
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen = []
    prod = 1
    for a in axes:
        if global_batch % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
        else:
            break
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


# Hardware constants for the roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s per link
