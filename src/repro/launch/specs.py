"""Shape/sharding stand-ins for the dry-run: ShapeDtypeStruct trees with
NamedShardings attached (no allocation), for every model input and state.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.ft.elastic import resolve_spec_for_mesh
from repro.launch.mesh import batch_axes_for
from repro.models.model import LM


def _sds(shape, dtype, mesh: Mesh, spec: P):
    spec = resolve_spec_for_mesh(spec, mesh)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> Dict:
    """ShapeDtypeStructs for one global batch (train / prefill)."""
    b, s = shape.global_batch, shape.seq_len
    ba = batch_axes_for(b, mesh)
    if cfg.is_encoder:
        return dict(
            features=_sds((b, s, cfg.feat_dim), jnp.float32, mesh,
                          P(ba, None, None)),
            labels=_sds((b, s), jnp.int32, mesh, P(ba, None)),
            mask=_sds((b, s), jnp.bool_, mesh, P(ba, None)),
        )
    return dict(
        tokens=_sds((b, s), jnp.int32, mesh, P(ba, None)),
        labels=_sds((b, s), jnp.int32, mesh, P(ba, None)),
    )


def state_specs(model: LM, mesh: Mesh) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct tree, NamedSharding tree) for the train state."""
    from repro.train.train_step import make_train_state, make_train_state_specs

    shapes = jax.eval_shape(
        lambda rng: make_train_state(model, rng), jax.random.PRNGKey(0))
    pspec = make_train_state_specs(model)
    shard_tree = jax.tree.map(
        lambda p: NamedSharding(mesh, resolve_spec_for_mesh(p, mesh)),
        pspec, is_leaf=lambda x: isinstance(x, P))
    sds = jax.tree.map(
        lambda sh, nd: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=nd),
        shapes, shard_tree)
    return sds, shard_tree


def params_specs(model: LM, mesh: Mesh,
                 fsdp: bool = True) -> Tuple[Any, Any]:
    """fsdp=False (serving): drop the 'data' (FSDP) axis from every param
    spec so weights stay TP-resident — no per-step param all-gather on the
    decode path (opt 'serve_params_resident')."""
    specs_holder = {}

    def f(rng):
        params, specs = model.init(rng)
        specs_holder["s"] = specs
        return params

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))

    def resolve(p: P) -> P:
        p = resolve_spec_for_mesh(p, mesh)
        if not fsdp:
            fixed = []
            for e in p:
                if e == "data":
                    fixed.append(None)
                elif isinstance(e, (tuple, list)):
                    kept = tuple(a for a in e if a != "data")
                    fixed.append(kept if kept else None)
                else:
                    fixed.append(e)
            p = P(*fixed)
        return p

    shard_tree = jax.tree.map(
        lambda p: NamedSharding(mesh, resolve(p)),
        specs_holder["s"], is_leaf=lambda x: isinstance(x, P))
    sds = jax.tree.map(
        lambda sh, nd: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=nd),
        shapes, shard_tree)
    return sds, shard_tree


def _cache_leaf_spec(cfg: ArchConfig, key: str, ndim: int, batch_axes,
                     stacked: bool, slots: int) -> P:
    lead = (None,) if stacked else ()
    kv_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % 16 == 0
    ssm_ok = cfg.has_ssm and cfg.ssm_nheads % 16 == 0
    # when KV heads can't shard 16-way, shard the cache *sequence* dim over
    # 'model' instead (sequence-parallel decode: scores/softmax/out get
    # partial-sum collectives — tiny next to the cache-read traffic)
    seq_shard = (not kv_ok) and slots >= 4096 and slots % 16 == 0
    if key in ("k", "v"):
        return P(*lead, batch_axes, "model" if seq_shard else None,
                 "model" if kv_ok else None, None)
    if key in ("ckv", "krope"):
        mla_seq = slots >= 4096 and slots % 16 == 0
        return P(*lead, batch_axes, "model" if mla_seq else None, None)
    if key == "pos":
        if seq_shard or (cfg.attn_type == "mla" and slots >= 4096
                         and slots % 16 == 0):
            return P(*lead, "model")
        return P(*lead, None)
    if key == "state":
        return P(*lead, batch_axes, "model" if ssm_ok else None, None, None)
    if key == "conv":
        return P(*lead, batch_axes, None, None)
    return P(*([None] * ndim))


def cache_specs(model: LM, shape: ShapeConfig, mesh: Mesh) -> Any:
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    ba = batch_axes_for(b, mesh)
    stacked = cfg.layout == "scan"
    shapes = jax.eval_shape(lambda: model.init_caches(b, s))

    def slots_of(key: str, shp) -> int:
        if key in ("k", "v"):
            return shp[-3]
        if key in ("ckv", "krope"):
            return shp[-2]
        if key == "pos":
            return shp[-1]
        return 0

    def walk(prefix_key: str, node):
        if isinstance(node, dict):
            return {k: walk(k, v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(prefix_key, x) for x in node]
        spec = _cache_leaf_spec(cfg, prefix_key, node.ndim, ba, stacked,
                                slots_of(prefix_key, node.shape))
        return _sds(node.shape, node.dtype, mesh, spec)

    return walk("", shapes)


def decode_token_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    b = shape.global_batch
    ba = batch_axes_for(b, mesh)
    tok = _sds((b, 1), jnp.int32, mesh, P(ba, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return tok, pos
