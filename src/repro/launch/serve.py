"""Serving launcher: batched prefill + greedy decode.

    python -m repro.launch.serve --arch mamba2-370m --reduced \
        --batch 4 --prompt-len 16 --max-new 24
"""
import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch
    from repro.models.model import LM
    from repro.serve.serve_step import generate

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    model = LM(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.perf_counter()
    out = generate(model, params, prompt, args.max_new,
                   args.prompt_len + args.max_new + 1)
    dt = time.perf_counter() - t0
    toks = args.batch * args.max_new
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0])[:16].tolist())


if __name__ == "__main__":
    main()
