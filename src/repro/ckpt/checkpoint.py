"""Checkpointing: per-process shard files, async save, resharding restore.

Layout:  <dir>/step_<N>/proc_<i>.npz  + meta.json (step, tree structure,
global shapes). Each process writes only its addressable shards; restore
reassembles under any mesh (elastic restarts with a different device
count re-shard transparently because we save *global* arrays per leaf on
proc 0 for small trees, or per-shard slices with index metadata).

For the single-process CI environment this degrades to one npz — but the
code path (flatten -> shard slices -> write -> read -> device_put with
target sharding) is the multi-host one.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}/{k}" if prefix else k, node[k])
        elif isinstance(node, (list, tuple)):
            for i, x in enumerate(node):
                walk(f"{prefix}/{i}", x)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def _unflatten_like(template, flat: Dict[str, Any]):
    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}/{k}" if prefix else k, v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            out = [walk(f"{prefix}/{i}", x) for i, x in enumerate(node)]
            return type(node)(out) if isinstance(node, tuple) else out
        return flat[prefix]
    return walk("", template)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---------------- save ----------------
    def save(self, step: int, state) -> None:
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_state)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state) -> None:
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten_with_paths(host_state)
        proc = jax.process_index()
        np.savez(os.path.join(tmp, f"proc_{proc}.npz"),
                 **{k: v for k, v in flat.items()})
        meta = dict(step=step, time=time.time(),
                    keys=sorted(flat.keys()))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------- restore ----------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, shardings=None):
        """template: pytree with the target structure (shapes may come from
        eval_shape). shardings: optional matching tree of NamedSharding —
        restoring under a *different* mesh reshards automatically here."""
        path = os.path.join(self.dir, f"step_{step}")
        data = np.load(os.path.join(path, f"proc_{jax.process_index()}.npz"))
        flat = {k: data[k] for k in data.files}
        host_tree = _unflatten_like(template, flat)
        if shardings is None:
            return jax.tree.map(jax.numpy.asarray, host_tree)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), host_tree, shardings)
