"""Version-compat shims for the pinned jax (0.4.x).

Parts of the codebase target newer jax spellings (`jax.shard_map`,
`jax.set_mesh`, `jax.lax.pvary`, `jax.sharding.AxisType`); the pinned
environment predates them. Import the shims from here — they resolve to
the native API when it exists and to an equivalent fallback otherwise,
so the code runs unchanged on both sides.
"""
from __future__ import annotations

import contextlib

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pre-0.6 spelling
    from jax.experimental.shard_map import shard_map  # type: ignore # noqa: F401

# pvary arrived with the varying-type checker; earlier shard_map treats
# shard-local zeros as already device-varying, so identity is correct.
pvary = getattr(jax.lax, "pvary", lambda x, axes: x)

# the replication/varying checker kwarg was renamed across versions, and
# old checkers lack rules for while_loop bodies — resolve the spelling once
_SM_CHECK_KW = next(
    (kw for kw in ("check_vma", "check_rep")
     if kw in __import__("inspect").signature(shard_map).parameters),
    None,
)


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """shard_map with the static replication checker disabled (needed for
    bodies containing while_loop on jax versions whose checker has no
    rule for it; semantics are unchanged)."""
    kwargs = {_SM_CHECK_KW: False} if _SM_CHECK_KW else {}
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kwargs)


def set_mesh(mesh):
    """`jax.set_mesh(mesh)` context, or a no-op context before it existed
    (callers pass the mesh explicitly via shard_map/NamedSharding, so the
    ambient mesh is only a convenience)."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return contextlib.nullcontext()


def tpu_compiler_params(**kwargs):
    """Pallas TPU compiler params across the CompilerParams /
    TPUCompilerParams rename."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def make_mesh(shape, axes):
    """`jax.make_mesh` with AxisType.Auto axes where supported (older
    versions have no axis_types parameter and are Auto-only anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
