"""AdamW with fully-sharded optimizer state + LR schedules.

Optimizer moments inherit each parameter's PartitionSpec (params are
FSDP-sharded over 'data' × TP over 'model'), i.e. ZeRO-style sharded
optimizer state falls out of the sharding rules for free.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict:
    zeros = lambda p: jnp.zeros_like(p)
    return dict(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Dict, jax.Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw_update(params, grads, opt_state, cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        newp = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * p32)
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["mu"])
    flat_v = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = dict(mu=new_m, nu=new_v, step=step)
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
