"""Gradient compression for cross-pod reduction (distributed-opt tricks).

Two composable schemes, both with error feedback so compression error is
re-injected next step instead of lost:

  * top-k sparsification (keep the largest |g| fraction per tensor);
  * int8 row-wise quantisation (absmax scaling).

On a real multi-pod deployment the compress happens *before* the slow
cross-pod ('pod' axis) all-reduce and decompress after — `compressed_psum`
shows the shard_map form. Inside a single XLA program the intra-pod
reduction stays full precision (ICI is cheap); only the DCN hop is
compressed, matching standard hierarchical-allreduce practice.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def topk_compress(g: jax.Array, frac: float, err: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Keep top `frac` of entries (by |value|) of g + err; rest feeds err."""
    acc = g.astype(jnp.float32) + err
    flat = acc.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(acc) >= thresh
    sent = jnp.where(mask, acc, 0.0)
    new_err = acc - sent
    return sent.astype(g.dtype), new_err


def int8_quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Row-wise absmax int8. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    flat = g32.reshape(g32.shape[0], -1) if g32.ndim > 1 else g32[None, :]
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(shape)


def int8_roundtrip(g: jax.Array, err: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    acc = g.astype(jnp.float32) + err
    q, s = int8_quantize(acc)
    deq = int8_dequantize(q, s, acc.shape)
    return deq.astype(g.dtype), acc - deq


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed all-reduce over `axis_name` (use inside shard_map).

    Every participant first agrees on a global absmax scale (one scalar
    psum — negligible), quantises its local contribution to int8 with
    that shared scale, and the int32 sum is dequantised once. Wire bytes
    drop 4x vs f32 / 2x vs bf16 for the payload hop (the scheme used on
    the slow cross-pod 'pod' axis)."""
    g32 = g.astype(jnp.float32)
    gmax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
    scale = jnp.maximum(gmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int32)
    qsum = jax.lax.psum(q, axis_name)
    return qsum.astype(jnp.float32) * scale


def init_error_state(params) -> Dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
