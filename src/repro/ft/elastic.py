"""Fault tolerance & elasticity: failure detection, straggler mitigation,
elastic re-meshing. On real fleets failure signals come from the runtime
(missed heartbeats, NCCL/ICI timeouts); here the *policy* layer is real
and the signal layer is injectable so tests can simulate failures.

Policies implemented:
  * checkpoint/restart — trainer saves every k steps and restarts from the
    latest checkpoint after a step failure (see train/trainer.py);
  * straggler detection — EWMA of step time; a step slower than
    `straggler_factor` × EWMA raises a straggler event (on a fleet: evict
    + re-dispatch the shard; here: logged + counted, and the LGRASS group
    partitioner re-balances via its LPT packing);
  * elastic re-mesh — rebuild a smaller/larger mesh and reshard the
    checkpointed state onto it (`remesh_state`), exercising the same code
    path a real elastic resize uses (restore with different shardings).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class FaultConfig:
    ckpt_every: int = 50
    straggler_factor: float = 3.0
    max_restarts: int = 3
    ewma_alpha: float = 0.2


class StragglerMonitor:
    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self.ewma: Optional[float] = None
        self.events: List[Tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = (self.ewma is not None and
                        dt > self.cfg.straggler_factor * self.ewma)
        if is_straggler:
            self.events.append((step, dt))
        a = self.cfg.ewma_alpha
        self.ewma = dt if self.ewma is None else (1 - a) * self.ewma + a * dt
        return is_straggler


class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    def __init__(self, fail_steps=()):
        self.fail_steps = set(fail_steps)
        self.fired = set()

    def check(self, step: int) -> None:
        if step in self.fail_steps and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


def resolve_spec_for_mesh(p: P, mesh: Mesh) -> P:
    """Drop mesh axes that don't exist on this mesh (elastic downsizing
    from (pod,data,model) to (data,model) or a single-device mesh)."""
    names = set(mesh.axis_names)

    def fix(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*[fix(e) for e in p])


def remesh_state(state, spec_tree, new_mesh: Mesh):
    """Reshard a (host or device) state pytree onto a new mesh."""
    def place(x, p):
        sh = NamedSharding(new_mesh, resolve_spec_for_mesh(p, new_mesh))
        return jax.device_put(np.asarray(jax.device_get(x)), sh)

    return jax.tree.map(place, state, spec_tree,
                        is_leaf=lambda x: not isinstance(x, (dict, list)))
