"""Trainer: the fault-tolerant loop (checkpoint/restart, straggler
monitoring, deterministic data resume). One class, pure-step inside.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import Checkpointer
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.ft.elastic import FailureInjector, FaultConfig, StragglerMonitor
from repro.models.model import LM
from repro.optim.optimizer import OptConfig
from repro.train.train_step import make_train_state, make_train_step

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    micro_batches: int = 1
    compress: Optional[str] = None
    seed: int = 0


class Trainer:
    def __init__(self, model: LM, data: TokenPipeline, opt_cfg: OptConfig,
                 tcfg: TrainerConfig, ckpt_dir: str,
                 fault_cfg: Optional[FaultConfig] = None,
                 failure_injector: Optional[FailureInjector] = None):
        self.model = model
        self.data = data
        self.tcfg = tcfg
        self.fault_cfg = fault_cfg or FaultConfig()
        self.ckpt = Checkpointer(ckpt_dir)
        self.monitor = StragglerMonitor(self.fault_cfg)
        self.injector = failure_injector
        self.step_fn = jax.jit(
            make_train_step(model, opt_cfg,
                            micro_batches=tcfg.micro_batches,
                            compress=tcfg.compress),
            donate_argnums=(0,))
        self.restarts = 0
        self.history: list = []

    def _fresh_state(self):
        state = make_train_state(self.model, jax.random.PRNGKey(
            self.tcfg.seed))
        if self.tcfg.compress:
            from repro.optim.compression import init_error_state
            state["err"] = init_error_state(state["params"])
        return state

    def _try_restore(self, state):
        last = self.ckpt.latest_step()
        if last is None:
            return state, 0
        template = jax.tree.map(np.asarray, jax.device_get(state))
        restored = self.ckpt.restore(last, template)
        log.info("restored checkpoint at step %d", last)
        return jax.tree.map(jax.numpy.asarray, restored), last

    def run(self) -> Dict:
        state = self._fresh_state()
        state, start = self._try_restore(state)
        step = start
        while step < self.tcfg.total_steps:
            try:
                batch = self.data.batch(step)  # deterministic in step
                if self.injector is not None:
                    self.injector.check(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                if self.monitor.observe(step, dt):
                    log.warning("straggler at step %d: %.3fs (ewma %.3fs)",
                                step, dt, self.monitor.ewma)
                self.history.append(dict(step=step, loss=loss, dt=dt))
                if step % self.tcfg.log_every == 0:
                    log.info("step %d loss %.4f (%.1f ms)",
                             step, loss, dt * 1e3)
                step += 1
                if step % self.fault_cfg.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except Exception as e:  # node failure -> restart from ckpt
                self.restarts += 1
                if self.restarts > self.fault_cfg.max_restarts:
                    raise
                log.warning("step %d failed (%s); restart %d/%d",
                            step, e, self.restarts,
                            self.fault_cfg.max_restarts)
                state = self._fresh_state()
                state, step = self._try_restore(state)
        self.ckpt.save(step, state)
        self.ckpt.wait()
        return dict(state=state, history=self.history,
                    restarts=self.restarts,
                    stragglers=len(self.monitor.events))
