"""Training step: loss -> grads -> AdamW, with microbatch gradient
accumulation (lax.scan) and optional gradient compression w/ error
feedback. The step is one jit-compiled pure function over a TrainState
dict — the unit the dry-run lowers at 512 devices.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import LM
from repro.optim import compression as comp
from repro.optim.optimizer import OptConfig, adamw_update, init_opt_state


def make_train_state(model: LM, rng) -> Dict:
    params, _ = model.init(rng)
    state = dict(params=params, opt=init_opt_state(params))
    return state


def make_train_state_specs(model: LM) -> Dict:
    """PartitionSpec tree matching make_train_state (moments = params)."""
    from jax.sharding import PartitionSpec as P
    specs_holder = {}

    def f(rng):
        params, specs = model.init(rng)
        specs_holder["s"] = specs
        return params

    jax.eval_shape(f, jax.random.PRNGKey(0))
    pspecs = specs_holder["s"]
    return dict(
        params=pspecs,
        opt=dict(mu=pspecs, nu=pspecs, step=P()),
    )


def _split_microbatches(batch: Dict, k: int) -> Dict:
    def r(x):
        b = x.shape[0]
        assert b % k == 0, f"batch {b} not divisible by micro {k}"
        return x.reshape((k, b // k) + x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(
    model: LM,
    opt_cfg: OptConfig,
    micro_batches: int = 1,
    compress: Optional[str] = None,   # None | 'topk' | 'int8'
    topk_frac: float = 0.01,
    grad_shard_specs: Optional[Dict] = None,
    grad_sync_dtype: Optional[str] = None,  # e.g. 'bfloat16' (§Perf)
):
    """Returns train_step(state, batch) -> (state, metrics).

    grad_shard_specs: optional PartitionSpec tree matching params. When
    set, each microbatch's gradients are constrained to the *param*
    sharding inside the accumulation scan, so XLA emits one
    reduce-scatter per microbatch into a ZeRO-sharded accumulator
    instead of all-reducing full replicated gradients (≈2x less grad
    wire traffic; the accumulator is FSDP-sharded rather than
    replicated). §Perf opt 'grad_shard_accum'.
    """

    def loss_of(params, mb):
        loss, metrics = model.loss_fn(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def _constrain(grads):
        if grad_shard_specs is None:
            return grads
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.ft.elastic import resolve_spec_for_mesh
        from repro.models.sharding import current_mesh
        mesh = current_mesh()
        if mesh is None:
            return grads
        return jax.tree.map(
            lambda g, p: jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, resolve_spec_for_mesh(p, mesh))),
            grads, grad_shard_specs,
            is_leaf=lambda x: not isinstance(x, (dict, list)))

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]
        if micro_batches > 1:
            mbs = _split_microbatches(batch, micro_batches)

            def acc_body(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = grad_fn(params, mb)
                if grad_sync_dtype:
                    # cross-device reduction in bf16 halves grad wire
                    # bytes; accumulation stays f32 (upcast add)
                    grads = jax.tree.map(
                        lambda g: g.astype(grad_sync_dtype), grads)
                grads = _constrain(grads)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), gacc, grads)
                return (gacc, lacc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            g0 = _constrain(g0)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (g0, jnp.float32(0.0)),
                                           mbs)
            grads = jax.tree.map(lambda g: g / micro_batches, gsum)
            loss = lsum / micro_batches
        else:
            (loss, _), grads = grad_fn(params, batch)
            grads = _constrain(grads)

        if compress == "topk":
            errs = state["err"]
            out = jax.tree.map(
                lambda g, e: comp.topk_compress(g, topk_frac, e),
                grads, errs)
            grads = jax.tree.map(lambda o: o[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_err = jax.tree.map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        elif compress == "int8":
            errs = state["err"]
            out = jax.tree.map(lambda g, e: comp.int8_roundtrip(g, e),
                               grads, errs)
            grads = jax.tree.map(lambda o: o[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_err = jax.tree.map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))

        new_params, new_opt, om = adamw_update(params, grads,
                                               state["opt"], opt_cfg)
        new_state = dict(params=new_params, opt=new_opt)
        if compress:
            new_state["err"] = new_err
        metrics = dict(loss=loss, **om)
        return new_state, metrics

    return train_step
