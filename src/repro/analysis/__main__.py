"""CLI for the static-analysis pass: ``python -m repro.analysis``.

Exit codes: 0 — clean (all findings baselined); 1 — new lint findings
or failed jaxpr audits; 2 — a ``--seed-bug`` run whose injected bug
was caught (the expected outcome of a seeded run).

    python -m repro.analysis                    # lint + jaxpr audit
    python -m repro.analysis --skip-jaxpr src/  # lint only, other tree
    python -m repro.analysis --json report.json # machine-readable
    python -m repro.analysis --seed-bug inf-depth     # must exit != 0
    python -m repro.analysis --seed-bug pack-overflow # must exit != 0

The seeded bugs re-create the repo's two worst shipped bugs as witness
programs and assert the analyzers still catch them:

  * ``inf-depth`` — the PR 5 poisoning: an unreachable-depth sentinel
    (INT32_MAX) cast into float32 and multiplied by an edge weight
    without the ``finite_depth`` guard.
  * ``pack-overflow`` — the packed BFS relaxation key dist·(n+1)+id
    traced one past ``PACKED_KEY_MAX_N``, where it provably exceeds
    int32.
"""
from __future__ import annotations

import argparse
import json
import sys


def _seeded_bug(which: str):
    """Trace the witness program for the named historical bug and
    return the range findings (non-empty iff the analyzers work)."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.ranges import INT32_MAX, Interval, check_ranges
    from repro.core.bfs import PACKED_KEY_MAX_N

    if which == "inf-depth":
        # PR 5 regression: depth carries the INT32_MAX "unreachable"
        # sentinel; the buggy effective-weight path casts it straight
        # into f32 and multiplies by the edge weight — no clamp.
        def buggy_eff(depth, w):
            return depth.astype(jnp.float32) * w

        spec_i = jax.ShapeDtypeStruct((8,), jnp.int32)
        spec_f = jax.ShapeDtypeStruct((8,), jnp.float32)
        return check_ranges(
            buggy_eff,
            [Interval.of(0, 63, sentinel=INT32_MAX), Interval.of(0, 1)],
            spec_i, spec_f)

    if which == "pack-overflow":
        n = PACKED_KEY_MAX_N + 1

        def pack(dist, ids, base):
            return dist * base + ids

        spec = jax.ShapeDtypeStruct((8,), jnp.int32)
        return check_ranges(
            pack,
            [Interval.of(0, n), Interval.of(0, n), Interval.const(n + 1)],
            spec, spec, jax.ShapeDtypeStruct((), jnp.int32))

    raise SystemExit(f"unknown --seed-bug {which!r}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo static analysis: AST lint + jaxpr audit")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or trees to lint (default: src/repro)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the full machine-readable report here")
    ap.add_argument("--skip-jaxpr", action="store_true",
                    help="lint only; skip tracing the device programs")
    ap.add_argument("--baseline", default=None,
                    help="alternate baseline file (default: the "
                         "package's baseline.json)")
    ap.add_argument("--seed-bug", choices=("inf-depth", "pack-overflow"),
                    default=None,
                    help="inject a known historical bug as a witness "
                         "program; exits 2 when (and only when) the "
                         "analyzers catch it")
    ns = ap.parse_args(argv)

    report = {"lint": [], "suppressed": 0, "audits": [],
              "derived_constants": [], "seeded": None, "ok": True}
    rc = 0

    if ns.seed_bug:
        findings = _seeded_bug(ns.seed_bug)
        report["seeded"] = {
            "bug": ns.seed_bug,
            "caught": bool(findings),
            "findings": [str(f) for f in findings],
        }
        if findings:
            print(f"seeded bug '{ns.seed_bug}' CAUGHT:")
            for f in findings:
                print(f"  {f}")
            rc = 2
        else:
            print(f"seeded bug '{ns.seed_bug}' NOT caught — the "
                  f"analyzers have regressed", file=sys.stderr)
            report["ok"] = False
            rc = 0  # a miss must look "clean" so the CI seeded-run
            # assertion (`! python -m repro.analysis --seed-bug ...`)
            # fails loudly instead of passing by accident
        if ns.json:
            with open(ns.json, "w", encoding="utf-8") as f:
                json.dump(report, f, indent=2)
        return rc

    from repro.analysis.lint import (
        apply_baseline,
        load_baseline,
        run_lint,
    )

    findings = run_lint(ns.paths)
    new, suppressed = apply_baseline(findings, load_baseline(ns.baseline))
    report["lint"] = [f.as_dict() for f in new]
    report["suppressed"] = len(suppressed)
    for f in new:
        print(f.format())
    if new:
        rc = 1
        report["ok"] = False
    print(f"lint: {len(new)} new finding(s), {len(suppressed)} "
          f"baselined")

    if not ns.skip_jaxpr:
        from repro.analysis.jaxpr_audit import (
            check_derived_constants,
            standard_program_audits,
        )

        derived = check_derived_constants()
        report["derived_constants"] = derived
        for msg in derived:
            print(f"derived-constant: {msg}")
        audits = standard_program_audits()
        report["audits"] = [r.as_dict() for r in audits]
        bad = [r for r in audits if not r.ok]
        for r in bad:
            for msg in r.findings:
                print(f"audit[{r.name}]: {msg}")
        print(f"jaxpr audit: {len(audits)} programs, "
              f"{len(bad)} failing")
        if derived or bad:
            rc = 1
            report["ok"] = False

    if ns.json:
        with open(ns.json, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2)
    return rc


if __name__ == "__main__":
    sys.exit(main())
