"""Jaxpr-level audit of the public device programs.

Traces each public jit program (`jax.make_jaxpr` — no compile, no
device) over the bucket signatures `SparsifyService` actually serves,
then walks the closed jaxpr (recursively through pjit / while / scan /
cond sub-jaxprs) and asserts the pipeline's contracts:

  * **one dispatch** — the program traces as a single closed jaxpr with
    zero host-callback primitives, so the dispatch the service issues
    is the only host↔device transition: no hidden `device_get`, no
    debug callback, no infeed. (`dispatch_count` is 1 + the number of
    callback primitives found.)
  * **no f64 / weak-type leaks** — on the non-x64 leg no variable
    anywhere in the program may carry a 64-bit dtype, and the top-level
    outputs must be strongly typed (a weak output means a Python
    literal's promotion escaped the program boundary).
  * **loop budgets** — the while-loop COUNT is pinned per
    (program, bfs_engine) — the O(log n)/O(diameter) round loops are
    data-bounded by construction, but an accidental extra while is a
    regression this catches — and every scan trip count must be a
    documented O(log n) or O(chunk) constant, never O(L)/O(n)
    (`allowed_scan_lengths`): the contract behind the
    "O(log n)-round / ceil(n_crossing/C)-step" claims.
  * **derived constants** — the runtime's pack-switch constants
    (`bfs.PACKED_KEY_MAX_N`, `bfs.EULER_PACK_MAX_N`) must equal the
    values independently derived from the interval models in
    `analysis.ranges`, and the packed-key witness program must range-
    check clean at the switch point and FLAG one past it.

Audited program set (`standard_program_audits`): `phase1_device
[_batched]`, `lgrass_device[_batched]` (the donated variant shares the
trace — donation is a compile-time property, checked via
`launch.hlo_analysis.analyze_jitted`'s output_alias report), the
standalone `recover_device[_batched]`, and the spectral-probe
estimator; `audit_service` covers a live `SparsifyService`'s warmed
signature set through `ProgramSpec`s.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.ranges import (
    Interval,
    check_ranges,
    derive_euler_pack_max_n,
    derive_packed_key_max_n,
    packed_key_interval,
)

# Host-transition primitives: any of these inside a "single dispatch"
# program means the dispatch is not actually single.
FORBIDDEN_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed", "host_callback_call", "outside_call",
})

# 64-bit dtypes that may not appear outside the x64 leg.
_WIDE_DTYPES = ("float64", "int64", "uint64", "complex128")

# The documented while-loop budget per program family × BFS engine
# (schedule-independent; `parallel` uses a while in both engines):
#   phase-1 = graph BFS (1 while doubling / 2 while levels for the two
#   passes) + Borůvka rounds (1) + MARK scheduler (1) + group-layout
#   compaction (1); the fused program adds the recovery outer loop (1).
EXPECTED_WHILE: Dict[Tuple[str, str], int] = {
    ("phase1", "doubling"): 4,
    ("phase1", "levels"): 5,
    ("lgrass", "doubling"): 5,
    ("lgrass", "levels"): 6,
    ("recover", "-"): 1,
    ("probe", "-"): 0,
}


def _sub_jaxprs(eqn) -> Iterable[Any]:
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else [v]
        for sub in vs:
            if isinstance(sub, (jax.core.ClosedJaxpr, jax.core.Jaxpr)):
                yield sub


def collect_eqns(closed_or_jaxpr) -> List[Any]:
    """Every equation of the program, recursively through all
    sub-jaxprs (pjit bodies, while cond/body, scan body, cond branches)."""
    jx = getattr(closed_or_jaxpr, "jaxpr", closed_or_jaxpr)
    out: List[Any] = []

    def walk(j):
        for eqn in j.eqns:
            out.append(eqn)
            for sub in _sub_jaxprs(eqn):
                walk(getattr(sub, "jaxpr", sub))

    walk(jx)
    return out


def _all_avals(closed) -> Iterable[Any]:
    jx = closed.jaxpr
    for v in list(jx.invars) + list(jx.outvars) + list(jx.constvars):
        if hasattr(v, "aval"):
            yield v.aval
    for eqn in collect_eqns(closed):
        for v in list(eqn.invars) + list(eqn.outvars):
            av = getattr(v, "aval", None)
            if av is not None:
                yield av


@dataclasses.dataclass
class AuditReport:
    name: str
    n_eqns: int = 0
    n_while: int = 0
    scan_lengths: Tuple[int, ...] = ()
    dispatch_count: int = 1
    findings: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings

    def as_dict(self) -> dict:
        return dict(name=self.name, n_eqns=self.n_eqns,
                    n_while=self.n_while,
                    scan_lengths=list(self.scan_lengths),
                    dispatch_count=self.dispatch_count,
                    findings=list(self.findings), ok=self.ok)


def audit_program(
    name: str,
    fn: Callable,
    args: Sequence[Any],
    static_kwargs: Optional[dict] = None,
    *,
    expected_while: Optional[int] = None,
    allowed_scan_lengths: Optional[Iterable[int]] = None,
    allow_wide: Optional[bool] = None,
) -> AuditReport:
    """Trace `fn(*args, **static_kwargs)` and run every jaxpr check.

    args are arrays or `jax.ShapeDtypeStruct`s. allow_wide=None reads
    the live x64 flag (the x64 CI leg legitimately carries 64-bit
    dtypes). expected_while / allowed_scan_lengths=None skip the loop
    budget (used for ad-hoc programs without a documented budget).
    """
    static_kwargs = static_kwargs or {}
    if allow_wide is None:
        allow_wide = bool(jax.config.jax_enable_x64)
    rep = AuditReport(name=name)
    closed = jax.make_jaxpr(lambda *a: fn(*a, **static_kwargs))(*args)
    eqns = collect_eqns(closed)
    rep.n_eqns = len(eqns)

    # --- dispatch count / forbidden primitives -------------------------
    callbacks = [e.primitive.name for e in eqns
                 if e.primitive.name in FORBIDDEN_PRIMITIVES]
    rep.dispatch_count = 1 + len(callbacks)
    for cb in callbacks:
        rep.findings.append(
            f"host-callback primitive '{cb}' inside the device program "
            f"(dispatch is not single)")

    # --- dtype scan ----------------------------------------------------
    if not allow_wide:
        seen_wide = set()
        for av in _all_avals(closed):
            dt = str(getattr(av, "dtype", ""))
            if dt in _WIDE_DTYPES:
                seen_wide.add(dt)
        for dt in sorted(seen_wide):
            rep.findings.append(
                f"64-bit dtype {dt} leaked into the non-x64 program")
    for i, v in enumerate(closed.jaxpr.outvars):
        if getattr(getattr(v, "aval", None), "weak_type", False):
            rep.findings.append(
                f"output {i} is weakly typed (literal promotion escaped "
                f"the program)")

    # --- loop budget ---------------------------------------------------
    rep.n_while = sum(1 for e in eqns if e.primitive.name == "while")
    rep.scan_lengths = tuple(sorted(
        int(e.params["length"]) for e in eqns
        if e.primitive.name == "scan"))
    if expected_while is not None and rep.n_while != expected_while:
        rep.findings.append(
            f"while-loop count {rep.n_while} != documented budget "
            f"{expected_while}")
    if allowed_scan_lengths is not None:
        allowed = set(int(x) for x in allowed_scan_lengths)
        for ln in rep.scan_lengths:
            if ln not in allowed:
                rep.findings.append(
                    f"scan trip count {ln} outside the documented budget "
                    f"set {sorted(allowed)} (an O(L)/O(n) loop?)")
    return rep


# ---------------------------------------------------------------------
# derived constants
# ---------------------------------------------------------------------

def check_derived_constants() -> List[str]:
    """Assert the runtime pack-switch constants equal the values the
    interval models derive independently, and that the packed-key
    witness range-checks clean at the switch point and flags past it."""
    from repro.core import bfs

    findings: List[str] = []
    derived = derive_packed_key_max_n()
    if derived != bfs.PACKED_KEY_MAX_N:
        findings.append(
            f"bfs.PACKED_KEY_MAX_N={bfs.PACKED_KEY_MAX_N} != derived "
            f"int32-safe bound {derived}")
    if derive_euler_pack_max_n() != bfs.EULER_PACK_MAX_N:
        findings.append(
            f"bfs.EULER_PACK_MAX_N={bfs.EULER_PACK_MAX_N} != derived "
            f"u32 pack bound {derive_euler_pack_max_n()}")
    for n in (2, 1024, bfs.PACKED_KEY_MAX_N):
        model = packed_key_interval(n).hi
        if model != bfs.packed_key_bound(n):
            findings.append(
                f"packed_key_bound({n})={bfs.packed_key_bound(n)} "
                f"disagrees with interval model {model}")

    # the traced witness: key = dist * (n+1) + id on finite clamped dist
    def witness(dist, ids, base):
        return dist * base + ids

    def run(n: int) -> List:
        spec = jax.ShapeDtypeStruct((4,), jnp.int32)
        return check_ranges(
            witness,
            [Interval.of(0, n), Interval.of(0, n),
             Interval.const(n + 1)],
            spec, spec, jax.ShapeDtypeStruct((), jnp.int32))

    if run(bfs.PACKED_KEY_MAX_N):
        findings.append(
            f"packed-key witness flags at n=PACKED_KEY_MAX_N="
            f"{bfs.PACKED_KEY_MAX_N} (bound too loose)")
    if not run(bfs.PACKED_KEY_MAX_N + 1):
        findings.append(
            f"packed-key witness fails to flag at n=PACKED_KEY_MAX_N+1 "
            f"(bound not tight — the fallback switch is unverified)")
    return findings


# ---------------------------------------------------------------------
# standard program set + service audit
# ---------------------------------------------------------------------

def _lgrass_budget(n: int, L: int, schedule: str,
                   p1_chunk: Optional[int], chunk: int) -> set:
    """The documented scan-trip-count set for the fused pipeline:
    binary-lifting depth (log n), the MARK block size, the recovery
    replay block size — and nothing else."""
    from repro.core.pow2 import auto_chunk, log2_ceil

    allowed = {log2_ceil(n + 1), chunk}
    if schedule == "chunked":
        allowed.add(p1_chunk if p1_chunk is not None else auto_chunk(L))
    return allowed


def standard_program_audits(n: int = 64, L: int = 128, B: int = 2,
                            b_cap: int = 8) -> List[AuditReport]:
    """Audit the public jit programs at one representative signature.

    Covers both BFS engines for the fused and phase-1 programs (the
    serving default "doubling" plus the "levels" fallback), the
    standalone recovery units, and the spectral-probe estimator —
    every `@jax.jit` entry point a caller can dispatch.
    """
    from repro.core import spectral_probe as sp
    from repro.core.pow2 import log2_ceil
    from repro.core.recovery import recover_device, recover_device_batched
    from repro.core.sparsify import (
        lgrass_device,
        lgrass_device_batched,
        phase1_device,
        phase1_device_batched,
    )

    f = jax.ShapeDtypeStruct
    i32, f32, b8 = jnp.int32, jnp.float32, jnp.bool_
    e1 = (f((L,), i32), f((L,), i32), f((L,), f32))
    eB = (f((B, L), i32), f((B, L), i32), f((B, L), f32), f((B, L), b8))
    lev = log2_ceil(n + 1)
    reports: List[AuditReport] = []

    for eng in ("doubling", "levels"):
        reports.append(audit_program(
            f"phase1_device[{eng}]", phase1_device, e1,
            dict(n=n, bfs_engine=eng),
            expected_while=EXPECTED_WHILE[("phase1", eng)],
            allowed_scan_lengths=_lgrass_budget(n, L, "chunked", None, 32)))
        reports.append(audit_program(
            f"phase1_device_batched[{eng}]", phase1_device_batched, eB,
            dict(n=n, bfs_engine=eng),
            expected_while=EXPECTED_WHILE[("phase1", eng)],
            allowed_scan_lengths=_lgrass_budget(n, L, "chunked", None, 32)))
        reports.append(audit_program(
            f"lgrass_device[{eng}]", lgrass_device,
            e1 + (f((), i32),), dict(n=n, b_cap=b_cap, bfs_engine=eng),
            expected_while=EXPECTED_WHILE[("lgrass", eng)],
            allowed_scan_lengths=_lgrass_budget(n, L, "chunked", None, 32)))
        reports.append(audit_program(
            f"lgrass_device_batched[{eng}]", lgrass_device_batched,
            eB + (f((B,), i32),), dict(n=n, b_cap=b_cap, bfs_engine=eng),
            expected_while=EXPECTED_WHILE[("lgrass", eng)],
            allowed_scan_lengths=_lgrass_budget(n, L, "chunked", None, 32)))

    rec1 = (f((lev, n), i32), f((n,), i32), f((L,), i32), f((L,), i32),
            f((L,), i32), f((L,), b8), f((L,), b8), f((L,), i32),
            f((L,), b8), f((L,), i32), f((L,), b8), f((), i32))
    reports.append(audit_program(
        "recover_device", recover_device, rec1, dict(b_cap=b_cap),
        expected_while=EXPECTED_WHILE[("recover", "-")],
        allowed_scan_lengths={32}))
    recB = tuple(f((B,) + s.shape, s.dtype) for s in rec1[:-1]) \
        + (f((B,), i32),)
    reports.append(audit_program(
        "recover_device_batched", recover_device_batched, recB,
        dict(b_cap=b_cap),
        expected_while=EXPECTED_WHILE[("recover", "-")],
        allowed_scan_lengths={32}))

    n_iters = 16
    probe1 = (f((L,), i32), f((L,), i32), f((L,), f32), f((L,), b8),
              f((L,), i32), f((L,), i32), f((2,), jnp.uint32),
              f((), f32), f((), f32))
    reports.append(audit_program(
        "probe_edge_resistance", sp._probe_er_program, probe1,
        dict(n=n, n_probes=8, n_iters=n_iters, method="cheby",
             use_spmv_kernel=False),
        expected_while=EXPECTED_WHILE[("probe", "-")],
        allowed_scan_lengths={n_iters}))
    probeB = (f((B, L), i32), f((B, L), i32), f((B, L), f32),
              f((B, L), b8), f((B, 2), jnp.uint32), f((), f32),
              f((), f32))
    reports.append(audit_program(
        "probe_edge_resistance_batched", sp._probe_er_batched_program,
        probeB,
        dict(n=n, n_probes=8, n_iters=n_iters, method="cheby",
             use_spmv_kernel=False),
        expected_while=EXPECTED_WHILE[("probe", "-")],
        allowed_scan_lengths={n_iters}))
    return reports


def audit_service(svc, sizes=None, batch_sizes=(1,),
                  budgets=()) -> List[AuditReport]:
    """Audit every compiled-program signature of a `SparsifyService`.

    Each `ProgramSpec` (the service's own dispatch funnel, see
    `serve.sparsify_service.program_specs`) is traced and checked:
    exactly one dispatch per serving mode, no f64 on the non-x64 leg,
    loop budgets — for the EXACT static kwargs traffic runs.
    """
    reports = []
    for spec in svc.program_specs(sizes, batch_sizes=batch_sizes,
                                  budgets=budgets):
        kw = spec.static_kwargs
        reports.append(audit_program(
            spec.name, spec.fn, spec.args, kw,
            expected_while=EXPECTED_WHILE[("lgrass", kw["bfs_engine"])],
            allowed_scan_lengths=_lgrass_budget(
                kw["n"], spec.args[0].shape[-1], kw["schedule"],
                kw["p1_chunk"], kw["chunk"])))
    return reports
