"""Static analysis for the device pipeline: machine-checked invariants.

Every hard bug this repo has shipped and fixed — the int32 packed-key
overflow past n = PACKED_KEY_MAX_N, float32 INF-depth poisoning on
disconnected forests, x64 int-promotion breakage, hidden host syncs on
the async serving path — is a *statically detectable* property of the
traced program. This package turns those properties into enforced
contracts, in two layers:

  * **jaxpr auditor** (`jaxpr_audit` + `ranges`) — trace the public jit
    programs over the bucket signatures `SparsifyService` actually
    compiles, then walk the closed jaxprs: no f64 leaks outside the x64
    leg, no callback/host-sync primitives, loop budgets match the
    documented O(log n)/chunked shapes, and an interval-arithmetic
    range propagator proves every integer pack fits its dtype (the
    n ≈ 46k BFS fallback is now the *derived* constant
    `bfs.PACKED_KEY_MAX_N`, asserted here).
  * **AST lint** (`lint`, runnable as `python -m repro.analysis`) —
    repo-specific source rules (rule catalog in `lint.RULES`): no host
    numpy on device-path modules, pinned dtype factories, sanctioned
    host syncs only, padded edge-list functions must thread a mask,
    no stray callbacks. Findings carry rule IDs and file:line; the
    baseline file (`baseline.json`) suppresses the justified
    exceptions so CI fails only on regressions.

See README "Static analysis" for the rule catalog and CI contract
(`tier1-static`).
"""
from repro.analysis.jaxpr_audit import (  # noqa: F401
    AuditReport,
    audit_program,
    audit_service,
    check_derived_constants,
    collect_eqns,
    standard_program_audits,
)
from repro.analysis.lint import (  # noqa: F401
    Finding,
    RULES,
    apply_baseline,
    load_baseline,
    run_lint,
)
from repro.analysis.ranges import (  # noqa: F401
    Interval,
    RangeFinding,
    check_ranges,
    derive_packed_key_max_n,
    packed_key_interval,
)
