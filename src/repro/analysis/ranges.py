"""Interval-arithmetic range propagation over jaxprs.

The device pipeline packs integers aggressively — dist·(n+1)+id
relaxation keys, (tail,head) u32 radix pairs, pow2 bucket math — and
every pack carries an implicit "fits int32" proof in a comment. This
module makes those proofs machine-checked:

  * `Interval` — integer/float interval arithmetic with an optional
    out-of-band *sentinel* value (the INT32_MAX "unreachable" marker
    BFS depths carry). Sentinels model the pipeline's ∪ {INF} value
    sets exactly: `[0, n] ∪ {INF}` is `Interval(0, n, sentinel=INF)`,
    and arithmetic distinguishes "the finite range overflows" from
    "the sentinel escaped into arithmetic".
  * `propagate` / `check_ranges` — seed a traced program's inputs with
    intervals and walk its jaxpr, flagging each op whose result
    provably exceeds its dtype (`int-overflow`), casts a sentinel into
    float arithmetic (`sentinel-escape` — the PR 5 unclamped-INF-depth
    bug, caught statically), or narrows past its input range
    (`cast-overflow`). Unmodelled primitives yield TOP (unknown)
    intervals which never flag: the propagator under-approximates, so
    every finding is real.
  * symbolic bound derivation — `packed_key_interval(n)` is the
    checker-side model of `bfs.bfs_doubling`'s packed relaxation key;
    `derive_packed_key_max_n()` computes the largest int32-safe n from
    it, and the auditor asserts it equals the constant the runtime
    actually switches on (`bfs.PACKED_KEY_MAX_N`).

The select-refinement rule is what lets clean code pass: the guard
idiom ``jnp.where(x == SENTINEL, repl, x)`` (bfs.finite_depth) strips
the sentinel from the false branch, so downstream float casts are
provably sentinel-free — while the same cast *without* the guard is
flagged. Only explicitly seeded values and their derivations are
checked; loop carries are TOP (audit loop bodies via witness programs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INT32_MAX = 2 ** 31 - 1
INT32_MIN = -(2 ** 31)

_INT_BOUNDS = {
    "int8": (-(2 ** 7), 2 ** 7 - 1),
    "int16": (-(2 ** 15), 2 ** 15 - 1),
    "int32": (INT32_MIN, INT32_MAX),
    "int64": (-(2 ** 63), 2 ** 63 - 1),
    "uint8": (0, 2 ** 8 - 1),
    "uint16": (0, 2 ** 16 - 1),
    "uint32": (0, 2 ** 32 - 1),
    "uint64": (0, 2 ** 64 - 1),
}


def dtype_bounds(dtype) -> Optional[Tuple[int, int]]:
    return _INT_BOUNDS.get(np.dtype(dtype).name)


@dataclasses.dataclass(frozen=True)
class Interval:
    """[lo, hi] plus an optional out-of-band sentinel the value may
    also take (e.g. BFS depth ∈ [0, n-1] ∪ {INT32_MAX}). `unknown`
    marks TOP: nothing is known, and nothing derived from it flags."""

    lo: float = 0
    hi: float = 0
    sentinel: Optional[int] = None
    unknown: bool = False

    # -------------------------------------------------------- builders
    @staticmethod
    def top() -> "Interval":
        return Interval(unknown=True)

    @staticmethod
    def const(c) -> "Interval":
        c = float(c) if isinstance(c, float) else c
        return Interval(lo=c, hi=c)

    @staticmethod
    def of(lo, hi, sentinel: Optional[int] = None) -> "Interval":
        return Interval(lo=lo, hi=hi, sentinel=sentinel)

    # ---------------------------------------------------------- views
    def hull_with_sentinel(self) -> "Interval":
        """Fold the sentinel into the range (what arithmetic on the raw
        values actually sees)."""
        if self.unknown or self.sentinel is None:
            return self
        return Interval(min(self.lo, self.sentinel),
                        max(self.hi, self.sentinel))

    def fits(self, dtype) -> bool:
        b = dtype_bounds(dtype)
        if b is None or self.unknown:
            return True
        eff = self.hull_with_sentinel()
        return b[0] <= eff.lo and eff.hi <= b[1]

    def union(self, other: "Interval") -> "Interval":
        if self.unknown or other.unknown:
            return Interval.top()
        s = self.sentinel if self.sentinel is not None else other.sentinel
        if (self.sentinel is not None and other.sentinel is not None
                and self.sentinel != other.sentinel):
            # two distinct sentinels: fold both into the range
            return self.hull_with_sentinel().union(
                other.hull_with_sentinel())
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi),
                        sentinel=s)

    # ------------------------------------------------------ arithmetic
    def _binop(self, other: "Interval",
               f: Callable[[float, float], float]) -> "Interval":
        if self.unknown or other.unknown:
            return Interval.top()
        a, b = self.hull_with_sentinel(), other.hull_with_sentinel()
        vals = [f(a.lo, b.lo), f(a.lo, b.hi), f(a.hi, b.lo), f(a.hi, b.hi)]
        return Interval(min(vals), max(vals))

    def __add__(self, other):
        return self._binop(_coerce(other), lambda x, y: x + y)

    def __sub__(self, other):
        return self._binop(_coerce(other), lambda x, y: x - y)

    def __mul__(self, other):
        return self._binop(_coerce(other), lambda x, y: x * y)

    def min_(self, other):
        return self._binop(_coerce(other), min)

    def max_(self, other):
        return self._binop(_coerce(other), max)

    def neg(self):
        if self.unknown:
            return self
        h = self.hull_with_sentinel()
        return Interval(-h.hi, -h.lo)

    def taints_float(self) -> bool:
        """True when casting this value to float would launder the
        sentinel into arithmetic (the PR 5 poisoning)."""
        return (not self.unknown) and self.sentinel is not None


def _coerce(x) -> Interval:
    if isinstance(x, Interval):
        return x
    return Interval.const(x)


# ---------------------------------------------------------------------
# symbolic bound models (the checker side of the runtime constants)
# ---------------------------------------------------------------------

def packed_key_interval(n: int) -> Interval:
    """Model of `bfs.bfs_doubling`'s fused relaxation key at node count
    n: dist·(n+1) + id with dist clamped to [0, n] and id ∈ [0, n].
    Mirrors `bfs.packed_key_bound(n)` — the audit asserts both agree."""
    dist = Interval.of(0, n)
    node = Interval.of(0, n)
    return dist * Interval.const(n + 1) + node


def derive_packed_key_max_n() -> int:
    """Largest n for which the packed relaxation key provably fits
    int32, derived from the interval model (not from the runtime's own
    constant — that is the point: two independent derivations)."""
    # key_max = (n+1)^2 - 1 is monotone in n: binary search the switch.
    lo, hi = 1, 1 << 20
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if packed_key_interval(mid).fits(jnp.int32):
            lo = mid
        else:
            hi = mid - 1
    return lo


def euler_pack_interval(n: int) -> Interval:
    """Model of `bfs.root_tree_euler`'s u32 (tail << 16 | head) arc
    key: exact for tail, head ∈ [0, n]."""
    return Interval.of(0, n) * Interval.const(1 << 16) + Interval.of(0, n)


def derive_euler_pack_max_n() -> int:
    """Largest n whose (tail, head) pair packs into u32 with 16-bit
    fields — fields must not collide, so n itself is bounded by the
    field width, not just the u32 range."""
    n = (1 << 16) - 1
    assert euler_pack_interval(n).fits(jnp.uint32)
    return n


# ---------------------------------------------------------------------
# jaxpr propagation
# ---------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RangeFinding:
    kind: str          # "int-overflow" | "sentinel-escape" | "cast-overflow"
    primitive: str
    eqn_index: int     # index into the walked equation list
    detail: str

    def __str__(self):
        return (f"[{self.kind}] eqn {self.eqn_index} ({self.primitive}): "
                f"{self.detail}")


def _const_interval(val) -> Interval:
    arr = np.asarray(val)
    if arr.size == 0:
        return Interval.top()
    if arr.dtype == bool:
        return Interval.of(0, 1)
    if np.issubdtype(arr.dtype, np.floating):
        finite = arr[np.isfinite(arr)]
        if finite.size == 0:
            return Interval.top()
        return Interval.of(float(finite.min()), float(finite.max()))
    return Interval.of(int(arr.min()), int(arr.max()))


class _Env:
    """Var -> Interval map over one jaxpr, plus predicate provenance
    (`eq(x, K)` facts) for the select-refinement rule."""

    def __init__(self):
        self.vals: Dict[Any, Interval] = {}
        # pred var -> (operand var, const K) for eq-against-constant
        self.eq_facts: Dict[Any, Tuple[Any, int]] = {}

    def read(self, atom) -> Interval:
        if isinstance(atom, jax.core.Literal):
            return _const_interval(atom.val)
        return self.vals.get(atom, Interval.top())

    def write(self, var, iv: Interval):
        self.vals[var] = iv


_PASSTHROUGH = {
    "broadcast_in_dim", "reshape", "squeeze", "expand_dims", "slice",
    "transpose", "copy", "stop_gradient", "rev", "gather",
    "dynamic_slice",
}

_BOOL_OUT = {"eq", "ne", "lt", "le", "gt", "ge", "and", "or", "not",
             "xor", "is_finite", "reduce_and", "reduce_or"}


def _refine_select(env: _Env, eqn) -> Optional[Interval]:
    """select_n(pred, case_false, case_true) with pred == eq(x, K):
    the false branch is x with the sentinel K stripped (x != K there),
    the true branch is taken as-is. Returns the refined union, or None
    when the pattern doesn't apply."""
    pred = eqn.invars[0]
    fact = env.eq_facts.get(pred)
    if fact is None or len(eqn.invars) != 3:
        return None
    x_var, k = fact
    branches: List[Interval] = []
    for case_atom, taken_when_eq in ((eqn.invars[1], False),
                                     (eqn.invars[2], True)):
        iv = env.read(case_atom)
        if (not taken_when_eq) and case_atom is x_var and not iv.unknown:
            if iv.sentinel == k:
                iv = Interval(iv.lo, iv.hi)          # sentinel stripped
            elif iv.hi == k:
                iv = Interval(iv.lo, k - 1, iv.sentinel)
        branches.append(iv)
    return branches[0].union(branches[1])


def propagate(closed_jaxpr: jax.core.ClosedJaxpr,
              seeds: Sequence[Interval]) -> List[RangeFinding]:
    """Walk `closed_jaxpr` with input intervals `seeds` (one per invar,
    Interval.top() for "unknown"); return every provable range finding.

    Sub-jaxprs of inlined jits (pjit) and custom_jvp wrappers are
    recursed into with their operand intervals; loop bodies (while /
    scan / cond) are NOT — their carries are TOP by construction, so
    in-loop invariants need dedicated witness programs.
    """
    findings: List[RangeFinding] = []
    counter = [0]
    _propagate_open(closed_jaxpr.jaxpr,
                    [_const_interval(c) for c in closed_jaxpr.consts],
                    list(seeds), findings, counter, {})
    return findings


def _inner_eq_facts(env: _Env, outer_atoms, inner_vars) -> Dict:
    """Translate eq-against-constant facts across a call boundary:
    when both the predicate and its operand are passed into the
    sub-jaxpr, rebind the fact onto the callee's invars (jnp.where
    lowers its select_n inside a pjit, so refinement must follow)."""
    pos = {id(a): i for i, a in enumerate(outer_atoms)}
    facts = {}
    for i, atom in enumerate(outer_atoms):
        if isinstance(atom, jax.core.Literal):
            continue
        fact = env.eq_facts.get(atom)
        if fact is None:
            continue
        x_outer, k = fact
        j = pos.get(id(x_outer))
        if j is not None and i < len(inner_vars) and j < len(inner_vars):
            facts[inner_vars[i]] = (inner_vars[j], k)
    return facts


def _propagate_open(jaxpr, const_ivs, seed_ivs, findings, counter,
                    in_facts):
    env = _Env()
    env.eq_facts.update(in_facts)
    for var, iv in zip(jaxpr.constvars, const_ivs):
        env.write(var, iv)
    for var, iv in zip(jaxpr.invars, seed_ivs):
        env.write(var, iv)
    for eqn in jaxpr.eqns:
        idx = counter[0]
        counter[0] += 1
        name = eqn.primitive.name
        ins = [env.read(a) for a in eqn.invars]
        out_iv = Interval.top()

        if name in ("add", "sub", "mul"):
            a, b = ins[0], ins[1]
            if a.taints_float() or b.taints_float():
                pass  # int arithmetic on a sentinel: folded below
            op = {"add": lambda x, y: x + y,
                  "sub": lambda x, y: x - y,
                  "mul": lambda x, y: x * y}[name]
            out_iv = op(a, b)
            dt = eqn.outvars[0].aval.dtype
            if not out_iv.unknown and dtype_bounds(dt) is not None \
                    and not out_iv.fits(dt):
                findings.append(RangeFinding(
                    "int-overflow", name, idx,
                    f"result range [{out_iv.lo}, {out_iv.hi}] exceeds "
                    f"{np.dtype(dt).name}"))
                out_iv = Interval.top()
        elif name == "neg":
            out_iv = ins[0].neg()
        elif name == "max":
            out_iv = ins[0].max_(ins[1])
        elif name == "min":
            out_iv = ins[0].min_(ins[1])
        elif name == "clamp":
            lo_iv, x_iv, hi_iv = ins
            if not any(i.unknown for i in (lo_iv, x_iv, hi_iv)):
                out_iv = x_iv.max_(lo_iv).min_(hi_iv)
        elif name == "select_n":
            refined = _refine_select(env, eqn)
            if refined is not None:
                out_iv = refined
            elif len(ins) == 3:
                out_iv = ins[1].union(ins[2])
        elif name == "convert_element_type":
            src = ins[0]
            dt = eqn.outvars[0].aval.dtype
            if np.issubdtype(dt, np.floating) and src.taints_float():
                findings.append(RangeFinding(
                    "sentinel-escape", name, idx,
                    f"integer sentinel {src.sentinel} cast into "
                    f"{np.dtype(dt).name} arithmetic"))
                out_iv = Interval.top()
            elif not src.fits(dt):
                findings.append(RangeFinding(
                    "cast-overflow", name, idx,
                    f"range [{src.lo}, {src.hi}]"
                    + (f" ∪ {{{src.sentinel}}}" if src.sentinel is not None
                       else "")
                    + f" does not fit {np.dtype(dt).name}"))
                out_iv = Interval.top()
            else:
                out_iv = src
        elif name == "iota":
            size = int(np.prod(eqn.outvars[0].aval.shape)) or 1
            out_iv = Interval.of(0, size - 1)
        elif name in ("reduce_min", "reduce_max", "argmin", "argmax"):
            if name in ("argmin", "argmax"):
                sz = int(np.prod(eqn.invars[0].aval.shape)) or 1
                out_iv = Interval.of(0, sz - 1)
            else:
                out_iv = ins[0]
        elif name == "reduce_sum":
            src = ins[0]
            if not src.unknown:
                cnt = max(int(np.prod(eqn.invars[0].aval.shape)), 1)
                h = src.hull_with_sentinel()
                out_iv = Interval(min(h.lo * cnt, h.lo),
                                  max(h.hi * cnt, h.hi))
                dt = eqn.outvars[0].aval.dtype
                if dtype_bounds(dt) is not None and not out_iv.fits(dt):
                    findings.append(RangeFinding(
                        "int-overflow", name, idx,
                        f"sum bound [{out_iv.lo}, {out_iv.hi}] exceeds "
                        f"{np.dtype(dt).name}"))
                    out_iv = Interval.top()
        elif name in ("scatter_min", "scatter_max"):
            out_iv = ins[0].union(ins[-1])
        elif name in _PASSTHROUGH:
            out_iv = ins[0]
        elif name in _BOOL_OUT:
            out_iv = Interval.of(0, 1)
            if name == "eq":
                # record eq-against-constant facts for select refinement
                for x_atom, k_atom in ((eqn.invars[0], eqn.invars[1]),
                                       (eqn.invars[1], eqn.invars[0])):
                    kiv = env.read(k_atom)
                    if not kiv.unknown and kiv.lo == kiv.hi \
                            and not isinstance(x_atom, jax.core.Literal):
                        env.eq_facts[eqn.outvars[0]] = (x_atom, kiv.lo)
                        break
        elif name in ("pjit", "closed_call", "custom_jvp_call",
                      "custom_vjp_call", "remat", "checkpoint"):
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                if isinstance(sub, jax.core.ClosedJaxpr):
                    inner = sub.jaxpr
                    facts = _inner_eq_facts(env, eqn.invars, inner.invars)
                    outs = _propagate_open(
                        inner, [_const_interval(c) for c in sub.consts],
                        ins, findings, counter, facts)
                else:
                    facts = _inner_eq_facts(env, eqn.invars, sub.invars)
                    outs = _propagate_open(sub, [], ins, findings, counter,
                                           facts)
                for var, iv in zip(eqn.outvars, outs):
                    env.write(var, iv)
                continue
        # anything else: outputs stay TOP (under-approximation)

        for var in eqn.outvars:
            env.write(var, out_iv)
    return [env.read(v) for v in jaxpr.outvars]


def check_ranges(fn: Callable, seeds: Sequence[Interval], *args,
                 static_kwargs: Optional[dict] = None) -> List[RangeFinding]:
    """Trace `fn` over `args` (arrays or jax.ShapeDtypeStruct) and
    propagate `seeds` (one Interval per positional arg)."""
    static_kwargs = static_kwargs or {}
    closed = jax.make_jaxpr(lambda *a: fn(*a, **static_kwargs))(*args)
    flat_seeds: List[Interval] = []
    for s, a in zip(seeds, args):
        leaves = jax.tree_util.tree_leaves(a)
        flat_seeds.extend([s] * len(leaves))
    n_in = len(closed.jaxpr.invars)
    flat_seeds += [Interval.top()] * (n_in - len(flat_seeds))
    return propagate(closed, flat_seeds[:n_in])
