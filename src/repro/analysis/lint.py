"""AST lint pass: repo-specific source rules for the device pipeline.

The jaxpr auditor (`jaxpr_audit`) checks what the traced programs *do*;
this pass checks what the source *says* — catching the bug classes the
tracer can't see (a host `numpy` call silently de-jitting a path, an
unpinned dtype factory that flips meaning under `JAX_ENABLE_X64`, a
padded edge-list function that forgot to thread its validity mask).

Rules (IDs are stable; see README "Static analysis"):

  ANA001  host numpy MIXED into a jnp function on a device path. A
          function that uses only numpy is a host helper by
          construction; one that interleaves ``np.*`` with ``jnp.*``
          either de-jits silently or constant-folds a traced value.
          Modules under core/ kernels/ serve/ sparse/ are device
          paths; functions whose names end in ``_np``/``_numpy``/
          ``_host`` and the explicit host modules (``_host.py``,
          ``resistance.py``) are exempt by convention.
  ANA002  unpinned dtype factory on a device path: ``jnp.zeros/ones/
          empty/eye/arange/linspace`` without ``dtype=``. Under x64
          the default flips to f64/i64 and the program silently
          recompiles wide. ``full`` inherits its dtype from the fill
          value, so it is only flagged when the fill is a bare Python
          literal (weak type) and no ``dtype=`` is given.
  ANA003  host sync (``jax.device_get`` / ``.block_until_ready()``)
          outside the sanctioned sync points. Each legitimate sync
          (service drain, warmup, host-facing result decode) is
          baselined with a justification; a NEW sync fails CI.
  ANA004  padded edge-list function without a validity mask: a public
          function taking ``u``, ``v`` and ``n`` operates on the padded
          edge list and must accept a mask parameter
          (``edge_valid``/``edge_mask``/``tree_mask``/``valid``/
          ``mask``/``is_offtree``/``crossing``) or it will process
          garbage pad lanes.
  ANA005  callback primitive (``pure_callback``/``io_callback``/
          ``debug_callback``/``jax.debug.print``) — these re-enter the
          host mid-program and break the one-dispatch serving contract.

Findings carry (rule, path, line, symbol, message). `baseline.json`
sits next to this module: a list of ``{rule, path, symbol, reason}``
entries (symbol ``"*"`` matches the whole file) suppressing the
justified exceptions, so `python -m repro.analysis` fails only on
regressions.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

DEVICE_PATH_DIRS = ("core", "kernels", "serve", "sparse")
HOST_EXEMPT_FILES = ("_host.py", "resistance.py")
HOST_EXEMPT_SUFFIXES = ("_np", "_numpy", "_host")

DTYPE_FACTORIES = ("zeros", "ones", "empty", "full", "eye", "arange",
                   "linspace")
SYNC_ATTRS = ("device_get", "block_until_ready")
CALLBACK_ATTRS = ("pure_callback", "io_callback", "debug_callback")
MASK_PARAM_NAMES = ("edge_valid", "edge_mask", "tree_mask", "valid",
                    "mask", "is_offtree", "crossing")
# Known typed-scalar constructors that make a `full` fill value pin the
# dtype on its own.
TYPED_SCALAR_NAMES = frozenset({
    "int8", "int16", "int32", "int64", "uint8", "uint16", "uint32",
    "uint64", "float16", "bfloat16", "float32", "float64", "bool_",
})

RULES: Dict[str, str] = {
    "ANA001": "host numpy inside a device-path function",
    "ANA002": "dtype factory without an explicit dtype= pin",
    "ANA003": "host sync outside the sanctioned sync points",
    "ANA004": "padded edge-list function without a validity mask param",
    "ANA005": "host callback inside a device program",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    symbol: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.symbol}] " \
               f"{self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _attr_chain(node: ast.AST) -> str:
    """'jnp.zeros' / 'jax.debug.print' for an Attribute/Name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_typed_scalar_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = _attr_chain(node.func)
    return chain.split(".")[-1] in TYPED_SCALAR_NAMES


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, relpath: str, device_path: bool):
        self.path = path
        self.relpath = relpath
        self.device_path = device_path
        self.fname = os.path.basename(path)
        self.findings: List[Finding] = []
        self._func_stack: List[str] = []
        # per-function frames for the numpy/jnp mixing check (ANA001)
        self._np_uses: List[List[Tuple[ast.AST, str]]] = []
        self._uses_jnp: List[bool] = []
        # module-local aliases that resolve to numpy ("np", "numpy", ...)
        self.numpy_aliases = set()
        self.jnp_aliases = set()
        self.jax_aliases = set()

    # -- scope helpers -------------------------------------------------
    @property
    def symbol(self) -> str:
        return self._func_stack[-1] if self._func_stack else "<module>"

    def _emit(self, rule: str, node: ast.AST, message: str):
        self.findings.append(Finding(
            rule=rule, path=self.relpath,
            line=getattr(node, "lineno", 0), symbol=self.symbol,
            message=message))

    def _host_exempt(self, name: Optional[str] = None) -> bool:
        if self.fname in HOST_EXEMPT_FILES:
            return True
        names = self._func_stack + ([name] if name else [])
        return any(f.endswith(HOST_EXEMPT_SUFFIXES) for f in names)

    # -- imports -------------------------------------------------------
    def visit_Import(self, node: ast.Import):
        for a in node.names:
            root = a.name.split(".")[0]
            name = a.asname or root
            if a.name == "jax.numpy":
                self.jnp_aliases.add(name)
            elif root == "numpy":
                self.numpy_aliases.add(name)
            elif root == "jax":
                self.jax_aliases.add(name)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module == "jax" :
            for a in node.names:
                if a.name == "numpy":
                    self.jnp_aliases.add(a.asname or "numpy")
        self.generic_visit(node)

    # -- functions -----------------------------------------------------
    def _visit_func(self, node):
        self._check_mask_param(node)
        self._func_stack.append(node.name)
        self._np_uses.append([])
        self._uses_jnp.append(False)
        self.generic_visit(node)
        np_uses = self._np_uses.pop()
        mixed = self._uses_jnp.pop()
        self._func_stack.pop()
        if mixed and np_uses and self.device_path \
                and not self._host_exempt(node.name):
            sym = node.name
            for use, chain in np_uses:
                self.findings.append(Finding(
                    rule="ANA001", path=self.relpath,
                    line=getattr(use, "lineno", 0), symbol=sym,
                    message=f"host numpy call `{chain}` interleaved "
                            f"with jnp on a device path (de-jits or "
                            f"constant-folds a traced value)"))
        elif np_uses and self._np_uses:
            # nested host helper inside a traced function: the numpy
            # use belongs to the enclosing frame's mixing decision
            # only if the helper isn't name-exempt.
            if not node.name.endswith(HOST_EXEMPT_SUFFIXES):
                self._np_uses[-1].extend(np_uses)

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def _check_mask_param(self, node):
        if not self.device_path or node.name.startswith("_") \
                or self.fname in HOST_EXEMPT_FILES \
                or node.name.endswith(HOST_EXEMPT_SUFFIXES):
            return
        if self._func_stack:      # only module-level public API
            return
        args = node.args
        names = [a.arg for a in args.posonlyargs + args.args
                 + args.kwonlyargs]
        if "u" in names and "v" in names and "n" in names:
            if not any(nm in MASK_PARAM_NAMES for nm in names):
                self.findings.append(Finding(
                    rule="ANA004", path=self.relpath,
                    line=getattr(node, "lineno", 0), symbol=node.name,
                    message="public edge-list function takes "
                            "(u, v, .., n) but no validity-mask "
                            "parameter "
                            f"({', '.join(MASK_PARAM_NAMES[:3])}, ...)"
                            " — pad lanes will be processed as real "
                            "edges"))

    # -- calls / attribute use ----------------------------------------
    def visit_Attribute(self, node: ast.Attribute):
        chain = _attr_chain(node)
        root = chain.split(".")[0]
        if self._np_uses:
            # Buffer for the per-function mixing decision (ANA001):
            # record np.* uses; note jnp/jax use as the "traced" marker.
            if root in self.numpy_aliases:
                self._np_uses[-1].append((node, chain))
            elif root in self.jnp_aliases or root in self.jax_aliases \
                    or root == "lax":
                self._uses_jnp[-1] = True
        # don't recurse: _attr_chain consumed the whole chain
        for child in ast.iter_child_nodes(node):
            self.visit(child)

    def visit_Call(self, node: ast.Call):
        chain = _attr_chain(node.func)
        parts = chain.split(".")
        leaf = parts[-1]
        root = parts[0] if parts else ""

        # ANA002 — dtype factories on device paths (jnp only; host
        # numpy defaults don't feed traced programs directly)
        if self.device_path and leaf in DTYPE_FACTORIES \
                and root in self.jnp_aliases:
            # dtype may be keyword or positional: zeros/ones/empty take
            # it as arg 2, full as arg 3 (after the fill value)
            pos_slot = {"zeros": 1, "ones": 1, "empty": 1, "full": 2}
            has_dtype = any(kw.arg == "dtype" for kw in node.keywords) \
                or len(node.args) > pos_slot.get(leaf, 99)
            if leaf == "full":
                # dtype otherwise follows the fill value; only a bare
                # literal fill is weakly typed
                hazard = (len(node.args) >= 2
                          and isinstance(node.args[1], ast.Constant))
            else:
                hazard = True
            if not has_dtype and hazard:
                self._emit(
                    "ANA002", node,
                    f"`{chain}(...)` without dtype= — default dtype "
                    f"flips under JAX_ENABLE_X64 and recompiles wide")

        # ANA003 — host syncs
        if leaf in SYNC_ATTRS:
            self._emit(
                "ANA003", node,
                f"host sync `{chain}` — every sync point must be "
                f"sanctioned (baseline) or the async path stalls")

        # ANA005 — callbacks
        if leaf in CALLBACK_ATTRS or chain.endswith("debug.print"):
            self._emit(
                "ANA005", node,
                f"host callback `{chain}` re-enters the host "
                f"mid-program (breaks the one-dispatch contract)")
        self.generic_visit(node)


def _iter_py_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if d not in ("__pycache__", ".git")]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def _is_device_path(relpath: str) -> bool:
    parts = relpath.replace(os.sep, "/").split("/")
    return any(d in parts for d in DEVICE_PATH_DIRS)


def lint_file(path: str, relpath: Optional[str] = None) -> List[Finding]:
    relpath = relpath or path
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding(rule="ANA000", path=relpath,
                        line=e.lineno or 0, symbol="<module>",
                        message=f"syntax error: {e.msg}")]
    v = _Visitor(path, relpath, _is_device_path(relpath))
    v.visit(tree)
    return v.findings


def run_lint(paths: Sequence[str]) -> List[Finding]:
    """Lint files/trees; paths in findings are relative to the cwd."""
    findings: List[Finding] = []
    for p in paths:
        files = _iter_py_files(p) if os.path.isdir(p) else [p]
        for fp in files:
            findings.extend(lint_file(fp, os.path.relpath(fp)))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


# ---------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baseline.json")


def load_baseline(path: Optional[str] = None) -> List[dict]:
    path = path or BASELINE_PATH
    if not os.path.exists(path):
        return []
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    return list(data.get("suppressions", data) if isinstance(data, dict)
                else data)


def _baseline_matches(entry: dict, finding: Finding) -> bool:
    if entry.get("rule") != finding.rule:
        return False
    bpath = entry.get("path", "").replace("\\", "/")
    fpath = finding.path.replace("\\", "/")
    if not (fpath == bpath or fpath.endswith("/" + bpath)):
        return False
    sym = entry.get("symbol", "*")
    return sym == "*" or sym == finding.symbol


def apply_baseline(
    findings: Sequence[Finding], baseline: Sequence[dict],
) -> Tuple[List[Finding], List[Finding]]:
    """Split into (new, suppressed)."""
    new: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        if any(_baseline_matches(e, f) for e in baseline):
            suppressed.append(f)
        else:
            new.append(f)
    return new, suppressed
