"""Data pipeline: deterministic synthetic LM streams + binary-file shards.

Multi-host discipline: every process materialises only its addressable
slice (process_index/process_count), then `jax.make_array_from_process_local_data`
assembles the global array — identical code path on 1 host and 1000.
Determinism: batch i is a pure function of (seed, step, shard), so a
restarted/elastic job regenerates identical data from the checkpointed
step — no data-state checkpoint needed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"   # synthetic | file
    path: Optional[str] = None
    is_encoder: bool = False
    feat_dim: int = 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig, mesh: Optional[Mesh] = None,
                 batch_spec: Optional[P] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.batch_spec = batch_spec
        self.proc = jax.process_index()
        self.nproc = jax.process_count()
        assert cfg.global_batch % self.nproc == 0
        self.local_batch = cfg.global_batch // self.nproc
        if cfg.kind == "file":
            self._data = np.memmap(cfg.path, dtype=np.uint16, mode="r")

    def _host_batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed, step, self.proc))  # pure function of (seed, step, shard)
        if c.is_encoder:
            feats = rng.standard_normal(
                (self.local_batch, c.seq_len, c.feat_dim)).astype(np.float32)
            labels = rng.integers(0, c.vocab_size,
                                  (self.local_batch, c.seq_len),
                                  dtype=np.int64).astype(np.int32)
            mask = rng.random((self.local_batch, c.seq_len)) < 0.5
            return dict(features=feats, labels=labels, mask=mask)
        if c.kind == "file":
            n = len(self._data) - c.seq_len - 1
            starts = rng.integers(0, n, self.local_batch)
            toks = np.stack([self._data[s: s + c.seq_len + 1]
                             for s in starts]).astype(np.int32)
        else:
            # Markov-ish synthetic stream: learnable but non-trivial
            toks = rng.integers(0, c.vocab_size,
                                (self.local_batch, c.seq_len + 1),
                                dtype=np.int64)
            toks = ((toks + np.cumsum(toks % 7, axis=1)) %
                    c.vocab_size).astype(np.int32)
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:])

    def batch(self, step: int) -> Dict:
        host = self._host_batch(step)
        if self.mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        out = {}
        for k, v in host.items():
            spec = self.batch_spec if v.ndim >= 1 else P()
            sh = NamedSharding(self.mesh, spec)
            out[k] = jax.make_array_from_process_local_data(sh, v)
        return out

    def __iter__(self) -> Iterator[Dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
