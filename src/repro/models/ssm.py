"""Mamba2 / SSD layer (arXiv:2405.21060) — chunked train/prefill + O(1)
recurrent decode.

The SSD (state-space duality) form splits the sequence into chunks of Q:
inside a chunk the recurrence is evaluated as a masked attention-like
matmul (MXU-friendly quadratic-in-Q), across chunks a tiny recurrence
carries the (H, P, N) state — a lax.scan over S/Q steps. This is the
TPU-native layout: all heavy ops are dense einsums over
(chunk, heads, headdim, state).

Decode keeps state (B, H, P, N) and a rolling conv window — O(1) per token,
which is why mamba2/hymba are the long_500k-capable architectures.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import ParamSet, normal, rmsnorm
from repro.models.sharding import fsdp_use, shard


def _dims(cfg: ArchConfig):
    di = cfg.d_inner
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    h, p = cfg.ssm_nheads, cfg.ssm_headdim
    conv_dim = di + 2 * g * n
    d_in_proj = 2 * di + 2 * g * n + h
    return di, g, n, h, p, conv_dim, d_in_proj


def init_ssm(ps: ParamSet, rng, cfg: ArchConfig) -> None:
    d = cfg.d_model
    di, g, n, h, p, conv_dim, d_in_proj = _dims(cfg)
    keys = jax.random.split(rng, 4)
    h_axis = "ssm_heads" if h % 16 == 0 else "ssm_heads_rep"
    ps.add("in_proj", normal(keys[0], (d, d_in_proj), d ** -0.5),
           "embed", "ssm_inner" if h % 16 == 0 else None)
    ps.add("conv_w", normal(keys[1], (cfg.ssm_conv, conv_dim), 0.1),
           "conv", None)
    ps.add("conv_b", jnp.zeros((conv_dim,), jnp.float32), None)
    # A in [-1, -e]; dt bias ~ softplus^-1 of [1e-3, 1e-1] range
    ps.add("A_log", jnp.log(jnp.linspace(1.0, jnp.e, h, dtype=jnp.float32)),
           h_axis)
    ps.add("D", jnp.ones((h,), jnp.float32), h_axis)
    ps.add("dt_bias", jnp.full((h,), -2.0, jnp.float32), h_axis)
    ps.add("norm", jnp.ones((di,), jnp.float32), None)
    ps.add("out_proj", normal(keys[2], (di, d), di ** -0.5),
           "ssm_inner" if h % 16 == 0 else None, "embed")


def _split_in_proj(cfg, zxbcdt):
    di, g, n, h, p, conv_dim, _ = _dims(cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + conv_dim]
    dt = zxbcdt[..., di + conv_dim:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, carry: Optional[jax.Array] = None):
    """Depthwise causal conv, width K. carry: (B, K-1, C) previous inputs."""
    k = conv_w.shape[0]
    if carry is None:
        pad = jnp.zeros(xbc.shape[:1] + (k - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = carry.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + full[:, i: i + xbc.shape[1]] * conv_w[i].astype(xbc.dtype)
    out = out + conv_b.astype(xbc.dtype)
    new_carry = full[:, -(k - 1):] if k > 1 else None
    return jax.nn.silu(out), new_carry


def _ssd_chunked(xh, bm, cm, dt, a, chunk: int, h_axis=None):
    """SSD over chunks.

    xh: (B,S,H,P)  bm/cm: (B,S,G,N)  dt: (B,S,H)  a: (H,) negative.
    Returns y: (B,S,H,P), final_state: (B,H,P,N).

    h_axis: logical axis for the SSD head dim ('ssm_heads' when divisible
    by the TP extent) — constraining it keeps the (B,nc,q,q,H) intra-chunk
    tensors sharded 16-way instead of replicated.
    """
    b, s, h, p = xh.shape
    g, n = bm.shape[2], bm.shape[3]
    q = chunk
    s_orig = s
    if s % q != 0:
        # pad to a chunk multiple with dt=0 steps: decay=exp(0)=1 and the
        # update term carries dt=0, so the final state is unaffected.
        pad = q - s % q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // q
    rep = h // g

    def r4(t):  # (B,S,...) -> (B,nc,q,...)
        return t.reshape((b, nc, q) + t.shape[2:])

    xh_, bm_, cm_, dtc = r4(xh), r4(bm), r4(cm), r4(dt)
    bmh = jnp.repeat(bm_, rep, axis=3)                   # (B,nc,q,H,N)
    cmh = jnp.repeat(cm_, rep, axis=3)
    if h_axis:
        xh_ = shard(xh_, "batch", None, None, h_axis, None)
        bmh = shard(bmh, "batch", None, None, h_axis, None)
        cmh = shard(cmh, "batch", None, None, h_axis, None)
        dtc = shard(dtc, "batch", None, None, h_axis)
    da = dtc * a.astype(dtc.dtype)                       # (B,nc,q,H)
    da_cs = jnp.cumsum(da, axis=2)                       # inclusive
    da_tot = da_cs[:, :, -1:, :]                         # (B,nc,1,H)

    # intra-chunk: y_i += sum_{j<=i} C_i.B_j exp(cs_i - cs_j) dt_j x_j
    decay = jnp.exp(da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)  # (B,nc,q,q,H)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", cmh, bmh,
                    preferred_element_type=jnp.float32)          # (B,nc,q,q,H)
    w = cb * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(xh_.dtype), xh_)

    # chunk summary states: S_c = sum_j exp(cs_last - cs_j) dt_j B_j x_j^T
    sdecay = jnp.exp(da_tot - da_cs)                             # (B,nc,q,H)
    xw = xh_ * (sdecay * dtc)[..., None].astype(xh_.dtype)
    chunk_states = jnp.einsum("bcjhn,bcjhp->bchpn", bmh, xw)

    # inter-chunk recurrence (tiny): S_c' = S_{c-1}' * exp(da_tot_c) + S_c
    da_tot_c = da_tot[:, :, 0, :]                                # (B,nc,H)

    def step(carry, inp):
        st, dtot = inp
        new = carry * jnp.exp(dtot)[:, :, None, None].astype(carry.dtype) + st
        return new, carry  # emit state *before* this chunk

    init = jnp.zeros((b, h, p, n), xh.dtype)
    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(da_tot_c, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)                # (B,nc,H,P,N)

    # inter-chunk contribution: y_i += C_i . S_prev * exp(cs_i)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", cmh, prev_states)
    y_inter = y_inter * jnp.exp(da_cs)[..., None].astype(y_inter.dtype)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y[:, :s_orig], final


def ssm_forward(params: Dict, cfg: ArchConfig, x: jax.Array,
                return_state: bool = False):
    """Full-sequence SSD (train / prefill). x: (B,S,d)."""
    dt_ = x.dtype
    di, g, n, h, p, conv_dim, _ = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x,
                        fsdp_use(params["in_proj"], "embed",
                                 None).astype(dt_))
    z, xbc, dtr = _split_in_proj(cfg, zxbcdt)
    xbc, conv_carry = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs = xbc[..., :di]
    bm = xbc[..., di: di + g * n].reshape(*xbc.shape[:2], g, n)
    cm = xbc[..., di + g * n:].reshape(*xbc.shape[:2], g, n)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(*xs.shape[:2], h, p)
    h_axis = "ssm_heads" if h % 16 == 0 else None
    y, state = _ssd_chunked(xh, bm, cm, dt, a, cfg.ssm_chunk, h_axis=h_axis)
    y = y + xh * params["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(*y.shape[:2], di)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y,
                     fsdp_use(params["out_proj"], None,
                              "embed").astype(dt_))
    if return_state:
        return out, dict(state=state, conv=conv_carry)
    return out


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype) -> Dict:
    di, g, n, h, p, conv_dim, _ = _dims(cfg)
    return dict(
        state=jnp.zeros((batch, h, p, n), dtype),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    )


def ssm_decode(params: Dict, cfg: ArchConfig, x: jax.Array,
               cache: Dict) -> Tuple[jax.Array, Dict]:
    """One-token recurrent update. x: (B,1,d)."""
    dt_ = x.dtype
    di, g, n, h, p, conv_dim, _ = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x,
                        fsdp_use(params["in_proj"], "embed",
                                 None).astype(dt_))
    z, xbc, dtr = _split_in_proj(cfg, zxbcdt)
    xbc, conv_carry = _causal_conv(
        xbc, params["conv_w"], params["conv_b"], carry=cache["conv"])
    xs = xbc[..., :di]
    bm = xbc[..., di: di + g * n].reshape(xbc.shape[0], g, n)
    cm = xbc[..., di + g * n:].reshape(xbc.shape[0], g, n)
    dt = jax.nn.softplus(dtr.astype(jnp.float32)[:, 0] +
                         params["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(xs.shape[0], h, p)
    bmh = jnp.repeat(bm, h // g, axis=1)                 # (B,H,N)
    cmh = jnp.repeat(cm, h // g, axis=1)
    decay = jnp.exp(dt * a[None, :])                       # (B,H)
    upd = jnp.einsum("bhn,bhp->bhpn", bmh, xh * dt[..., None].astype(xh.dtype))
    state = cache["state"] * decay[:, :, None, None].astype(xh.dtype) + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, cmh)
    y = y + xh * params["D"].astype(y.dtype)[None, :, None]
    y = y.reshape(y.shape[0], 1, di)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y,
                     fsdp_use(params["out_proj"], None,
                              "embed").astype(dt_))
    return out, dict(state=state, conv=conv_carry)
