"""Logical-axis sharding (MaxText-style rules, mesh-optional).

Every tensor dimension gets a *logical* name; `AXIS_RULES` maps logical
names to mesh axes of the production mesh ('pod', 'data', 'model').
When no mesh is active (CPU smoke tests) every constraint is a no-op, so
model code is written once and runs anywhere.

Param placement (ZeRO-3 / FSDP + TP hybrid):
    embed dim  -> 'data'   (fully-sharded params, all-gathered per layer;
                            XLA's latency-hiding scheduler overlaps the
                            all-gather with the previous layer's compute)
    heads/mlp/experts/vocab -> 'model' (tensor parallel)
    batch      -> ('pod', 'data')  (pods are pure data parallel)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[str, None, Tuple[Union[str, None], ...]]

AXIS_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",        # FSDP shard dim of params
    "embed_tp": "model",    # opt: d_model of the lookup table on 'model'
    "act_embed": None,      # activations keep d_model replicated
    "heads": "model",
    "kv_heads": "model",    # only applied when divisible (see spec())
    "kv_heads_rep": None,   # non-divisible kv heads: replicate
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "vocab": "model",
    "ssm_heads": "model",
    "ssm_heads_rep": None,
    "ssm_inner": "model",
    "state": None,
    "conv": None,
    "lora": None,
    "stack": None,          # scan-stacked layer axis
    "cache_seq": None,
    "frame": None,
}

_state = threading.local()

# Beyond-paper optimisation toggles (see EXPERIMENTS.md §Perf). Default
# OFF = paper-faithful baseline; the dry-run's --opt flag flips them for
# the hillclimbed variants.
OPTIMIZATIONS = set()


def opt_enabled(name: str) -> bool:
    return name in OPTIMIZATIONS


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            from repro import compat

            with compat.set_mesh(mesh):
                yield
        else:
            yield
    finally:
        _state.mesh = prev


def spec(*logical: Axes) -> P:
    """Translate logical dim names to a PartitionSpec via AXIS_RULES.
    Mesh axes absent from the currently active mesh are dropped, so the
    same model code lowers on the multi-pod, single-pod and host meshes."""
    mesh = current_mesh()
    names = set(mesh.axis_names) if mesh is not None else None

    def fix(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry
                         if names is None or a in names)
            return kept if kept else None
        if names is not None and entry not in names:
            return None
        return entry

    out = []
    for name in logical:
        if name is None:
            out.append(None)
        else:
            out.append(fix(AXIS_RULES.get(name, None)))
    return P(*out)


def shard(x: jax.Array, *logical: Axes) -> jax.Array:
    """with_sharding_constraint when a mesh is active; identity otherwise."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(*logical))
    )


def named_sharding(mesh: Mesh, p: P) -> NamedSharding:
    return NamedSharding(mesh, p)


def fsdp_use(w: jax.Array, *logical: Axes) -> jax.Array:
    """Constrain an FSDP-sharded weight at its use site to be gathered
    over the 'data' axis (logical 'embed' -> replicated) while keeping
    its 'model' (TP) sharding.

    Why: with params P('data','model') and batch P(('pod','data')), the
    SPMD partitioner resolves x @ w by partial-summing the contraction
    and ALL-REDUCING ACTIVATIONS per matmul (expensive: per-layer, per-
    microbatch). Forcing the weight gathered makes XLA emit one weight
    all-gather per layer instead — ~8x less wire on chameleon train_4k
    (§Perf opt 'fsdp_gather_weights'). No-op unless the opt is enabled.
    """
    if not opt_enabled("fsdp_gather_weights"):
        return w
    fixed = tuple(None if name == "embed" else name for name in logical)
    return shard(w, *fixed)
