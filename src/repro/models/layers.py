"""Shared layers: params-with-specs, norms, RoPE, MLPs, embeddings.

Params are plain nested dicts of jnp arrays; a parallel tree of
PartitionSpec is built at init time through `ParamSet` so pjit
in_shardings can be derived mechanically for any architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.sharding import spec


class ParamSet:
    """Collects (value, logical axes) leaves; splits into params/specs."""

    def __init__(self):
        self.values: Dict[str, Any] = {}
        self.specs: Dict[str, Any] = {}

    def add(self, name: str, value: jax.Array, *axes) -> jax.Array:
        self.values[name] = value
        self.specs[name] = spec(*axes)
        return value

    def sub(self, name: str, other: "ParamSet") -> None:
        self.values[name] = other.values
        self.specs[name] = other.specs


def normal(rng, shape, std, dtype=jnp.float32):
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


# ------------------------- norms -------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def init_rmsnorm(ps: ParamSet, name: str, dim: int, axis="act_embed"):
    ps.add(name, jnp.ones((dim,), jnp.float32), axis)


# ------------------------- RoPE -------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) — rotate pairs (d, d + D/2). positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------- MLP -------------------------
def init_mlp(ps: ParamSet, rng, d_model: int, d_ff: int, act: str):
    from repro.models.sharding import opt_enabled
    k1, k2, k3 = jax.random.split(rng, 3)
    std_in = d_model ** -0.5
    std_out = d_ff ** -0.5
    if act == "swiglu" and opt_enabled("fused_qkv"):
        # gate and up projections fused: one bwd dx all-reduce, not two.
        # layout (d, 2, f): the split dim is unsharded, so selecting
        # gate/up halves never reshards the 'model'-sharded f dim
        ps.add("wig", normal(k1, (d_model, 2, d_ff), std_in),
               "embed", None, "mlp")
    else:
        ps.add("wi", normal(k1, (d_model, d_ff), std_in), "embed", "mlp")
        if act == "swiglu":
            ps.add("wg", normal(k3, (d_model, d_ff), std_in),
                   "embed", "mlp")
    ps.add("wo", normal(k2, (d_ff, d_model), std_out), "mlp", "embed")


def mlp(params, x: jax.Array, act: str) -> jax.Array:
    from repro.models.sharding import fsdp_use
    dt = x.dtype
    if "wig" in params:
        hg = jnp.einsum("...d,dgf->...gf", x,
                        fsdp_use(params["wig"], "embed", None,
                                 "mlp").astype(dt))
        h = jax.nn.silu(hg[..., 1, :]) * hg[..., 0, :]
    else:
        h = jnp.einsum("...d,df->...f", x,
                       fsdp_use(params["wi"], "embed", "mlp").astype(dt))
        if act == "swiglu":
            g = jnp.einsum("...d,df->...f", x,
                           fsdp_use(params["wg"], "embed", "mlp").astype(dt))
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h,
                      fsdp_use(params["wo"], "mlp", "embed").astype(dt))


# ------------------------- embeddings -------------------------
def init_embed(ps: ParamSet, rng, vocab: int, d_model: int,
               tie: bool) -> None:
    from repro.models.sharding import opt_enabled
    k1, k2 = jax.random.split(rng)
    if opt_enabled("embed_dshard"):
        # lookup table sharded on d_model ('model') and replicated over
        # 'data': token gathers partition trivially (no vocab-shard
        # gather fallback / full-table all-gather per step). The lm_head
        # stays vocab-sharded so logits + CE remain 'model'-sharded.
        ps.add("embedding", normal(k1, (vocab, d_model), 0.02),
               None, "embed_tp")
    else:
        ps.add("embedding", normal(k1, (vocab, d_model), 0.02),
               "vocab", "embed")
    if not tie:
        ps.add("lm_head", normal(k2, (vocab, d_model), d_model ** -0.5),
               "vocab", "embed")


def embed_tokens(params, tokens: jax.Array, dtype) -> jax.Array:
    return params["embedding"].astype(dtype)[tokens]


def lm_logits(params, x: jax.Array, tie: bool) -> jax.Array:
    from repro.models.sharding import fsdp_use
    table = params["embedding"] if tie else params["lm_head"]
    table = fsdp_use(table, "vocab", None)
    return jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  real_vocab: int = 0) -> jax.Array:
    """Mean CE in f32; padded vocab columns are excluded via masking."""
    logits = logits.astype(jnp.float32)
    if real_vocab and real_vocab < logits.shape[-1]:
        neg = jnp.full((logits.shape[-1] - real_vocab,), -1e9, jnp.float32)
        logits = logits.at[..., real_vocab:].add(neg)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
