"""Mixture-of-Experts FFN with capacity-bounded index dispatch.

Design (scales to the 512-chip mesh without giant one-hots):

  * router: (T, E) logits -> top-k experts per token + softmax gates.
  * dispatch: each (token, slot) pair gets its *rank within its expert*
    via `repro.core.sort.bucket_ranks` — the same chunked one-hot prefix
    machinery as the paper's radix sort (LGRASS §3.3), reused as the MoE
    combiner. Tokens beyond capacity C = ceil(T·k·cf / E) are dropped
    (standard GShard-style drop policy).
  * compute: gather (E, C, d) -> batched expert einsum -> scatter-add.

Sharding: experts are laid out on the 'model' axis; tokens are sharded on
('pod','data') and *replicated* over 'model' (same as dense TP), so expert
compute needs no all-to-all — each model shard computes its experts'
contribution and the psum at the end is the same collective a dense TP
FFN already pays. Expert weights: (E, d, f) sharded P('model','data',·).

Padded experts (granite 40 -> 48) are masked to -inf in the router.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.sort import bucket_ranks
from repro.models.layers import ParamSet, normal
from repro.models.sharding import fsdp_use, shard


def init_moe(ps: ParamSet, rng, cfg: ArchConfig) -> None:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    ps.add("router", normal(k1, (d, e), d ** -0.5), "embed", None)
    ps.add("wi", normal(k2, (e, d, f), d ** -0.5),
           "experts", "embed", "expert_mlp")
    if cfg.act == "swiglu":
        ps.add("wg", normal(k4, (e, d, f), d ** -0.5),
               "experts", "embed", "expert_mlp")
    ps.add("wo", normal(k3, (e, f, d), f ** -0.5),
           "experts", "expert_mlp", "embed")


def _capacity(t: int, k: int, e: int, cf: float) -> int:
    c = int(t * k * cf / e) + 1
    return max(4, (c + 3) // 4 * 4)


def moe_ffn(params: Dict, cfg: ArchConfig, x: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    Dispatch is *per sequence* (GShard groups == batch rows): every gather
    and scatter indexes along S only, so the batch dimension stays aligned
    with its ('pod','data') shards and no cross-shard collective is
    generated; experts stay sharded on 'model'.
    """
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k

    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(dt))
    logits = logits.astype(jnp.float32)
    if cfg.real_n_experts and cfg.real_n_experts < e:
        pad_mask = jnp.arange(e) >= cfg.real_n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e9, logits)
    gates_all = jax.nn.softmax(logits, axis=-1)               # (B, S, E)
    gate_vals, expert_idx = jax.lax.top_k(gates_all, k)       # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(gates_all, axis=(0, 1))
    frac = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (b * s * k))
    aux = cfg.router_aux_coef * e * jnp.sum(density * frac)

    cap = _capacity(s, k, e, cfg.capacity_factor)
    flat_e = expert_idx.reshape(b, s * k)                     # (B, S*k)
    pos_in_e = jax.vmap(lambda fe: bucket_ranks(fe, e))(flat_e)
    keep = pos_in_e < cap
    # (B, E, C) token table; dropped pairs scatter out-of-bounds; empty
    # slots point at row S (zero pad)
    tok_of_slot = jnp.full((b, e, cap), s, jnp.int32)
    slot_e = jnp.where(keep, flat_e, e)      # e is out of bounds -> dropped
    slot_c = jnp.where(keep, pos_in_e, 0)
    token_ids = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None], (b, s * k))
    barange = jnp.broadcast_to(jnp.arange(b)[:, None], (b, s * k))
    tok_of_slot = tok_of_slot.at[barange, slot_e, slot_c].set(
        token_ids, mode="drop")
    gate_of_slot = jnp.zeros((b, e, cap), jnp.float32).at[
        barange, slot_e, slot_c].set(gate_vals.reshape(b, s * k),
                                     mode="drop")

    xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), dt)], axis=1)
    xe = jnp.take_along_axis(
        xpad[:, :, None, :],
        tok_of_slot.reshape(b, e * cap)[:, :, None, None], axis=1
    ).reshape(b, e, cap, d)
    xe = shard(xe, "batch", "experts", None, None)
    h = jnp.einsum("becd,edf->becf", xe,
                   fsdp_use(params["wi"], "experts", None,
                            "expert_mlp").astype(dt))
    if cfg.act == "swiglu":
        g = jnp.einsum("becd,edf->becf", xe,
                       fsdp_use(params["wg"], "experts", None,
                                "expert_mlp").astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("becf,efd->becd", h,
                    fsdp_use(params["wo"], "experts",
                             "expert_mlp", None).astype(dt))
    ye = ye * gate_of_slot[..., None].astype(dt)

    y = jnp.zeros((b, s + 1, d), dt).at[
        jnp.arange(b)[:, None], tok_of_slot.reshape(b, e * cap)].add(
        ye.reshape(b, e * cap, d))
    return y[:, :s], aux
