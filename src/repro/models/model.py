"""Model assembly: decoder LMs (dense / GQA / MLA / MoE / SSM / hybrid)
and the encoder (hubert), with scan-over-layers or unrolled layouts.

Public surface (all pure functions over a params pytree):
    init(rng)                      -> (params, specs)
    loss_fn(params, batch)         -> (loss, metrics)      [train]
    encode(params, batch)          -> logits               [encoder]
    prefill(params, tokens)        -> (last_logits, caches)
    init_caches(batch, max_len)    -> caches
    decode_step(params, tok, pos, caches) -> (logits, caches)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamSet,
    cross_entropy,
    embed_tokens,
    init_embed,
    init_mlp,
    init_rmsnorm,
    lm_logits,
    mlp,
    normal,
    rmsnorm,
)
from repro.models.sharding import shard, spec


def _act_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ------------------------- layer init -------------------------
def _init_layer(rng, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    ps = ParamSet()
    keys = jax.random.split(rng, 4)
    if cfg.has_attention:
        init_rmsnorm(ps, "attn_norm", cfg.d_model)
        sub = ParamSet()
        if cfg.attn_type == "mla":
            attn.init_mla(sub, keys[0], cfg)
        else:
            attn.init_gqa(sub, keys[0], cfg)
        ps.sub("attn", sub)
    if cfg.has_ssm:
        init_rmsnorm(ps, "ssm_norm", cfg.d_model)
        sub = ParamSet()
        ssm_mod.init_ssm(sub, keys[1], cfg)
        ps.sub("ssm", sub)
    if cfg.d_ff > 0:
        init_rmsnorm(ps, "mlp_norm", cfg.d_model)
        sub = ParamSet()
        if cfg.is_moe:
            moe_mod.init_moe(sub, keys[2], cfg)
        else:
            init_mlp(sub, keys[2], cfg.d_model, cfg.d_ff, cfg.act)
        ps.sub("mlp", sub)
    return ps.values, ps.specs


# ------------------------- block apply -------------------------
def _block(cfg: ArchConfig, p: Dict, x: jax.Array, positions: jax.Array,
           *, window: Optional[int], mode: str,
           cache: Optional[Dict] = None, pos: Optional[jax.Array] = None):
    """One transformer/ssm/hybrid block. Returns (x, new_cache, aux)."""
    aux = jnp.float32(0.0)
    new_cache: Dict[str, Any] = {}
    causal = not cfg.is_encoder

    mixer_out = None
    if cfg.has_attention and cfg.has_ssm:  # hybrid (hymba): parallel heads
        h_in = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        if mode == "decode":
            a_out, new_cache["attn"] = attn.gqa_decode(
                p["attn"], cfg, h_in, pos, cache["attn"], window)
            s_out, new_cache["ssm"] = ssm_mod.ssm_decode(
                p["ssm"], cfg, h_in, cache["ssm"])
        else:
            a_out = attn.gqa_attention(p["attn"], cfg, h_in, positions,
                                       causal=causal, window=window)
            if mode == "prefill":
                new_cache["attn"] = attn.gqa_fill_cache(
                    p["attn"], cfg, h_in, positions, cache["attn"], window)
                s_out, ssm_state = ssm_mod.ssm_forward(
                    p["ssm"], cfg, h_in, return_state=True)
                new_cache["ssm"] = ssm_state
            else:
                s_out = ssm_mod.ssm_forward(p["ssm"], cfg, h_in)
        mixer_out = 0.5 * (a_out + s_out)
    elif cfg.has_attention:
        h_in = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        if cfg.attn_type == "mla":
            if mode == "decode":
                mixer_out, new_cache["attn"] = attn.mla_decode(
                    p["attn"], cfg, h_in, pos, cache["attn"])
            else:
                mixer_out = attn.mla_attention(p["attn"], cfg, h_in,
                                               positions, causal=causal)
                if mode == "prefill":
                    new_cache["attn"] = attn.mla_fill_cache(
                        p["attn"], cfg, h_in, positions, cache["attn"])
        else:
            if mode == "decode":
                mixer_out, new_cache["attn"] = attn.gqa_decode(
                    p["attn"], cfg, h_in, pos, cache["attn"], window)
            else:
                mixer_out = attn.gqa_attention(p["attn"], cfg, h_in,
                                               positions, causal=causal,
                                               window=window)
                if mode == "prefill":
                    new_cache["attn"] = attn.gqa_fill_cache(
                        p["attn"], cfg, h_in, positions, cache["attn"],
                        window)
    elif cfg.has_ssm:  # pure SSM (mamba2)
        h_in = rmsnorm(x, p["ssm_norm"], cfg.norm_eps)
        if mode == "decode":
            mixer_out, new_cache["ssm"] = ssm_mod.ssm_decode(
                p["ssm"], cfg, h_in, cache["ssm"])
        elif mode == "prefill":
            mixer_out, st = ssm_mod.ssm_forward(p["ssm"], cfg, h_in,
                                                return_state=True)
            new_cache["ssm"] = st
        else:
            mixer_out = ssm_mod.ssm_forward(p["ssm"], cfg, h_in)

    if mixer_out is not None:
        x = x + mixer_out
        x = shard(x, "batch", "seq", "act_embed")

    if cfg.d_ff > 0:
        h_in = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
        if cfg.is_moe:
            y, aux = moe_mod.moe_ffn(p["mlp"], cfg, h_in)
        else:
            y = mlp(p["mlp"], h_in, cfg.act)
        x = x + y
        x = shard(x, "batch", "seq", "act_embed")
    return x, new_cache, aux


def _layer_window(cfg: ArchConfig, idx: int) -> Optional[int]:
    if cfg.sliding_window is None:
        return None
    return None if idx in cfg.global_layers else cfg.sliding_window


# ------------------------- model -------------------------
@dataclasses.dataclass
class LM:
    cfg: ArchConfig

    # ---------- init ----------
    def init(self, rng) -> Tuple[Dict, Dict]:
        cfg = self.cfg
        ps = ParamSet()
        k_emb, k_layers, k_front = jax.random.split(rng, 3)
        init_embed(ps, k_emb, cfg.vocab_size, cfg.d_model, cfg.tie_embeddings)
        init_rmsnorm(ps, "final_norm", cfg.d_model)
        if cfg.frontend == "audio":
            sub = ParamSet()
            sub.add("proj", normal(k_front, (cfg.feat_dim, cfg.d_model),
                                   cfg.feat_dim ** -0.5), "frame", "embed")
            ps.sub("frontend", sub)
        params, specs = dict(ps.values), dict(ps.specs)

        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        _, layer_spec = _init_layer(jax.random.PRNGKey(0), cfg)
        if cfg.layout == "scan":
            stacked = jax.vmap(
                functools.partial(_init_layer_values, cfg=cfg))(layer_keys)
            params["layers"] = stacked
            specs["layers"] = jax.tree.map(
                lambda p: _prepend_none(p), layer_spec,
                is_leaf=_is_pspec)
        else:
            params["layers"] = [
                _init_layer_values(k, cfg) for k in layer_keys]
            specs["layers"] = [layer_spec for _ in range(cfg.n_layers)]
        return params, specs

    # ---------- train ----------
    def loss_fn(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        if cfg.is_encoder:
            logits = self.encode(params, batch)
            loss = cross_entropy(logits, batch["labels"], batch["mask"],
                                 cfg.real_vocab_size)
            return loss, {"loss": loss}
        x, positions = self._embed_inputs(params, batch)
        x, aux = self._run_layers_train(params, x, positions)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_logits(params, x, cfg.tie_embeddings)
        ce = cross_entropy(logits, batch["labels"],
                           batch.get("mask"), cfg.real_vocab_size)
        loss = ce + aux
        return loss, {"loss": loss, "ce": ce, "aux": aux}

    def encode(self, params: Dict, batch: Dict) -> jax.Array:
        cfg = self.cfg
        feats = batch["features"].astype(_act_dtype(cfg))
        x = jnp.einsum("btf,fd->btd", feats,
                       params["frontend"]["proj"].astype(feats.dtype))
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, _ = self._run_layers_train(params, x, positions)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return lm_logits(params, x, cfg.tie_embeddings)

    # ---------- serve ----------
    def init_caches(self, batch: int, max_len: int) -> Any:
        cfg = self.cfg
        dt = _act_dtype(cfg)

        def one(idx: int) -> Dict:
            c: Dict[str, Any] = {}
            window = _layer_window(cfg, idx)
            if cfg.has_attention:
                if cfg.attn_type == "mla":
                    c["attn"] = attn.init_mla_cache(cfg, batch, max_len, dt)
                else:
                    c["attn"] = attn.init_gqa_cache(cfg, batch, max_len,
                                                    window, dt)
            if cfg.has_ssm:
                c["ssm"] = ssm_mod.init_ssm_cache(cfg, batch, dt)
            return c

        if cfg.layout == "scan":
            caches = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[one(i) for i in range(cfg.n_layers)])
            return caches
        return [one(i) for i in range(cfg.n_layers)]

    def prefill(self, params: Dict, tokens: jax.Array, caches: Any
                ) -> Tuple[jax.Array, Any]:
        cfg = self.cfg
        x, positions = self._embed_inputs(params, {"tokens": tokens})
        if cfg.layout == "scan":
            def body(carry, xs):
                xc = carry
                p_l, c_l = xs
                window = cfg.sliding_window  # scan models: uniform window
                xc, nc, _ = _block(cfg, p_l, xc, positions, window=window,
                                   mode="prefill", cache=c_l)
                return xc, nc
            x, new_caches = jax.lax.scan(body, x,
                                         (params["layers"], caches))
        else:
            new_caches = []
            for i, (p_l, c_l) in enumerate(zip(params["layers"], caches)):
                x, nc, _ = _block(cfg, p_l, x, positions,
                                  window=_layer_window(cfg, i),
                                  mode="prefill", cache=c_l)
                new_caches.append(nc)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_logits(params, x[:, -1:, :], cfg.tie_embeddings)
        return logits[:, 0, :], new_caches

    def decode_step(self, params: Dict, tok: jax.Array, pos: jax.Array,
                    caches: Any) -> Tuple[jax.Array, Any]:
        """tok: (B, 1) int32; pos: scalar int32 absolute position."""
        cfg = self.cfg
        x = embed_tokens(params, tok, _act_dtype(cfg))
        x = shard(x, "batch", "seq", "act_embed")
        positions = jnp.broadcast_to(pos, tok.shape).astype(jnp.int32)
        if cfg.layout == "scan":
            def body(carry, xs):
                xc = carry
                p_l, c_l = xs
                xc, nc, _ = _block(cfg, p_l, xc, positions,
                                   window=cfg.sliding_window, mode="decode",
                                   cache=c_l, pos=pos)
                return xc, nc
            x, new_caches = jax.lax.scan(body, x,
                                         (params["layers"], caches))
        else:
            new_caches = []
            for i, (p_l, c_l) in enumerate(zip(params["layers"], caches)):
                x, nc, _ = _block(cfg, p_l, x, positions,
                                  window=_layer_window(cfg, i),
                                  mode="decode", cache=c_l, pos=pos)
                new_caches.append(nc)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_logits(params, x, cfg.tie_embeddings)
        return logits[:, 0, :], new_caches

    # ---------- internals ----------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed_tokens(params, tokens, _act_dtype(cfg))
        x = shard(x, "batch", "seq", "act_embed")
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return x, positions

    def _run_layers_train(self, params, x, positions):
        cfg = self.cfg
        aux_total = jnp.float32(0.0)
        if cfg.layout == "scan":
            def body(carry, p_l):
                xc, aux = carry
                xc, _, a = _block(cfg, p_l, xc, positions,
                                  window=cfg.sliding_window, mode="train")
                return (xc, aux + a), None
            body_fn = jax.checkpoint(body) if cfg.remat else body
            (x, aux_total), _ = jax.lax.scan(
                body_fn, (x, aux_total), params["layers"])
        else:
            for i, p_l in enumerate(params["layers"]):
                fn = functools.partial(
                    _block, cfg, p_l, window=_layer_window(cfg, i),
                    mode="train")
                if cfg.remat:
                    fn = jax.checkpoint(
                        lambda xc, pp=p_l, ww=_layer_window(cfg, i):
                        _block(cfg, pp, xc, positions, window=ww,
                               mode="train"))
                    x, _, a = fn(x)
                else:
                    x, _, a = _block(cfg, p_l, x, positions,
                                     window=_layer_window(cfg, i),
                                     mode="train")
                aux_total = aux_total + a
        return x, aux_total / max(self.cfg.n_layers, 1)


def _init_layer_values(rng, cfg: ArchConfig) -> Dict:
    return _init_layer(rng, cfg)[0]


def _is_pspec(x):
    from jax.sharding import PartitionSpec
    return isinstance(x, PartitionSpec)


def _prepend_none(p):
    from jax.sharding import PartitionSpec
    return PartitionSpec(None, *tuple(p))
