"""Attention: GQA (with RoPE, causal / bidirectional / sliding-window,
ring-buffer KV cache) and MLA (multi-head latent attention with compressed
latent cache + absorbed decode).

Caches
------
GQA full:    {k, v: (B, S_max, Kv, hd), pos: (S_max,) abs positions (-1 empty)}
GQA window:  same arrays with S_max = window, written mod window (ring).
MLA:         {ckv: (B, S_max, r_kv), krope: (B, S_max, d_r), pos: (S_max,)}
SSM caches live in ssm.py.

Decode computes scores against every cache slot with a validity mask —
fixed shapes, no dynamic slicing, which is what both XLA SPMD and the
Pallas kernel path want.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import ParamSet, apply_rope, normal, rmsnorm
from repro.models.sharding import fsdp_use, shard

NEG_INF = -1e9


# ======================= GQA =======================
def init_gqa(ps: ParamSet, rng, cfg: ArchConfig) -> None:
    from repro.models.sharding import opt_enabled
    d, h, kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    std = d ** -0.5
    kv_axis = "kv_heads" if kv % 16 == 0 else "kv_heads_rep"
    # fusion is only expressible when the q/k/v split points align with
    # the 16-way 'model' shard boundaries of the fused head dim
    fuse_ok = (h % 16 == 0 and kv % 16 == 0
               and h % ((h + 2 * kv) // 16) == 0)
    if opt_enabled("fused_qkv") and fuse_ok:
        # one (d, h+2kv, hd) matmul: the backward dx needs ONE partial-sum
        # all-reduce instead of three (§Perf opt 'fused_qkv')
        ps.add("wqkv", normal(k1, (d, h + 2 * kv, hd), std),
               "embed", "heads", "head_dim")
    else:
        ps.add("wq", normal(k1, (d, h, hd), std),
               "embed", "heads", "head_dim")
        ps.add("wk", normal(k2, (d, kv, hd), std),
               "embed", kv_axis, "head_dim")
        ps.add("wv", normal(k3, (d, kv, hd), std),
               "embed", kv_axis, "head_dim")
    ps.add("wo", normal(k4, (h, hd, d), (h * hd) ** -0.5),
           "heads", "head_dim", "embed")


def _qkv(params, cfg: ArchConfig, x, dt):
    h, kv = cfg.n_heads, cfg.n_kv_heads
    kv_ax = "kv_heads" if kv % 16 == 0 else "kv_heads_rep"
    if "wqkv" in params:
        qkv = jnp.einsum(
            "bsd,dhk->bshk", x,
            fsdp_use(params["wqkv"], "embed", "heads", None).astype(dt))
        return qkv[:, :, :h], qkv[:, :, h:h + kv], qkv[:, :, h + kv:]
    q = jnp.einsum("bsd,dhk->bshk", x,
                   fsdp_use(params["wq"], "embed", "heads", None).astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x,
                   fsdp_use(params["wk"], "embed", kv_ax, None).astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x,
                   fsdp_use(params["wv"], "embed", kv_ax, None).astype(dt))
    return q, k, v


def _gqa_scores(q, k, n_kv):
    """q: (B,S,H,hd) k: (B,T,Kv,hd) -> (B,Kv,G,S,T) f32 scores."""
    b, s, h, hd = q.shape
    g = h // n_kv
    q = q.reshape(b, s, n_kv, g, hd)
    return jnp.einsum("bskgd,btkd->bkgst", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(p, v, h):
    b, kv, g, s, t = p.shape
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v)
    return out.reshape(b, s, h, v.shape[-1])


# above this many query positions the reference path computes attention in
# query chunks (lax.scan) so no (S, S) score tensor is ever materialised —
# the jnp analogue of the flash kernel's tiling.
ATTN_CHUNK_THRESHOLD = 8192
ATTN_CHUNK = 1024


def _masked_softmax_attend(q, k, v, n_kv, scale, qpos, kpos, causal, window):
    """q: (B,Sq,H,hd) vs full k/v: (B,T,Kv,hd); qpos (B,Sq), kpos (B,T)."""
    scores = _gqa_scores(q, k, n_kv) * scale
    qi = qpos[:, :, None]
    kj = kpos[:, None, :]
    mask = jnp.ones((qpos.shape[0], qpos.shape[1], kpos.shape[1]), bool)
    if causal:
        mask = mask & (kj <= qi)
    if window is not None:
        mask = mask & (kj > qi - window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(p, v, q.shape[2])


def _banded_swa(q, k, v, positions, n_kv, scale, window):
    """Sliding-window attention as a banded two-block computation:
    each window-sized q chunk attends only to [its own, previous] k
    chunks — O(S·2w) scores instead of O(S²). Exact for w-divisible S."""
    b, s, h, hd = q.shape
    w = window
    nw = s // w
    qc = q.reshape(b, nw, w, h, hd)
    kc = k.reshape(b, nw, w, n_kv, hd)
    vc = v.reshape(b, nw, w, n_kv, hd)
    k2 = jnp.concatenate([jnp.roll(kc, 1, axis=1), kc], axis=2)
    v2 = jnp.concatenate([jnp.roll(vc, 1, axis=1), vc], axis=2)
    pq = positions.reshape(b, nw, w)
    pk_prev = jnp.roll(pq, 1, axis=1)
    # chunk 0 has no previous chunk: mark rolled positions invalid
    first = (jnp.arange(nw) == 0)[None, :, None]
    pk_prev = jnp.where(first, -1, pk_prev)
    pk = jnp.concatenate([pk_prev, pq], axis=2)          # (b, nw, 2w)
    g = h // n_kv
    qg = qc.reshape(b, nw, w, n_kv, g, hd)
    scores = jnp.einsum("bnwkgd,bntkd->bnkgwt", qg, k2,
                        preferred_element_type=jnp.float32) * scale
    qi = pq[:, :, None, None, :, None]
    kj = pk[:, :, None, None, None, :]
    mask = (kj >= 0) & (kj <= qi) & (kj > qi - w)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnkgwt,bntkd->bnwkgd", p.astype(v2.dtype), v2)
    return out.reshape(b, s, h, hd)


def gqa_attention(
    params: Dict,
    cfg: ArchConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    use_flash: bool = False,
) -> jax.Array:
    """Self-attention over full sequences (train / prefill)."""
    dt = x.dtype
    hd = cfg.resolved_head_dim
    q, k, v = _qkv(params, cfg, x, dt)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads" if cfg.n_kv_heads % 16 == 0
              else "kv_heads_rep", None)
    s = q.shape[1]
    if (window is not None and causal and not use_flash
            and s % window == 0 and s >= 2 * window):
        out = _banded_swa(q, k, v, positions, cfg.n_kv_heads,
                          hd ** -0.5, window)
        out = shard(out, "batch", "seq", "heads", None)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    if use_flash:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window)
    elif s > ATTN_CHUNK_THRESHOLD and s % ATTN_CHUNK == 0:
        # query-chunked exact attention: peak memory O(C*S) per step
        nq = s // ATTN_CHUNK
        qc = q.reshape(q.shape[0], nq, ATTN_CHUNK, *q.shape[2:])
        pc = positions.reshape(positions.shape[0], nq, ATTN_CHUNK)

        def chunk_body(_, inp):
            q_i, qpos_i = inp
            o = _masked_softmax_attend(q_i, k, v, cfg.n_kv_heads,
                                       hd ** -0.5, qpos_i, positions,
                                       causal, window)
            return None, o

        _, out = jax.lax.scan(
            chunk_body, None,
            (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(pc, 1, 0)))
        out = jnp.moveaxis(out, 0, 1).reshape(q.shape)
    else:
        out = _masked_softmax_attend(q, k, v, cfg.n_kv_heads, hd ** -0.5,
                                     positions, positions, causal, window)
    out = shard(out, "batch", "seq", "heads", None)
    return jnp.einsum("bshk,hkd->bsd",
                      out, fsdp_use(params["wo"], "heads", None,
                                    "embed").astype(dt))


def init_gqa_cache(cfg: ArchConfig, batch: int, max_len: int,
                   window: Optional[int], dtype) -> Dict:
    slots = min(window, max_len) if window else max_len
    hd = cfg.resolved_head_dim
    return dict(
        k=jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
        v=jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
        pos=jnp.full((slots,), -1, jnp.int32),
    )


def gqa_fill_cache(params, cfg, x, positions, cache, window) -> Dict:
    """Prefill: write K/V of a full prompt into the cache (last `slots`)."""
    dt = x.dtype
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    k = apply_rope(k, positions, cfg.rope_theta)
    slots = cache["k"].shape[1]
    s = k.shape[1]
    if window:
        # only the last `slots` positions survive the ring buffer
        take = min(s, slots)
        idxt = (positions[0, -take:]) % slots
        kc = cache["k"].at[:, idxt].set(k[:, -take:])
        vc = cache["v"].at[:, idxt].set(v[:, -take:])
        pc = cache["pos"].at[idxt].set(positions[0, -take:].astype(jnp.int32))
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
        pc = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions[0].astype(jnp.int32), 0, axis=0)
    return dict(k=kc, v=vc, pos=pc)


def gqa_decode(
    params: Dict,
    cfg: ArchConfig,
    x: jax.Array,              # (B, 1, d)
    pos: jax.Array,            # scalar int32 — absolute position
    cache: Dict,
    window: Optional[int],
) -> Tuple[jax.Array, Dict]:
    dt = x.dtype
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    posb = jnp.broadcast_to(pos, (x.shape[0], 1))
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    slots = cache["k"].shape[1]
    slot = (pos % slots) if window else pos
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    pc = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), pos, jnp.int32), slot, axis=0)

    scores = _gqa_scores(q, kc, cfg.n_kv_heads) * (hd ** -0.5)  # (B,Kv,G,1,T)
    valid = (pc >= 0) & (pc <= pos)
    if window is not None:
        valid = valid & (pc > pos - window)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(p, vc, cfg.n_heads)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, dict(k=kc, v=vc, pos=pc)


# ======================= MLA =======================
def init_mla(ps: ParamSet, rng, cfg: ArchConfig) -> None:
    d, h = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    keys = jax.random.split(rng, 6)
    ps.add("q_a", normal(keys[0], (d, rq), d ** -0.5), "embed", "lora")
    ps.add("q_a_norm", jnp.ones((rq,), jnp.float32), "lora")
    ps.add("q_b", normal(keys[1], (rq, h, dn + dr), rq ** -0.5),
           "lora", "heads", None)
    ps.add("kv_a", normal(keys[2], (d, rkv + dr), d ** -0.5), "embed", "lora")
    ps.add("kv_a_norm", jnp.ones((rkv,), jnp.float32), "lora")
    ps.add("kv_b", normal(keys[3], (rkv, h, dn + dv), rkv ** -0.5),
           "lora", "heads", None)
    ps.add("wo", normal(keys[4], (h, dv, d), (h * dv) ** -0.5),
           "heads", None, "embed")


def _mla_qkv_latent(params, cfg, x, positions):
    dt = x.dtype
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    rkv = cfg.kv_lora_rank
    cq = jnp.einsum("bsd,dr->bsr", x, params["q_a"].astype(dt))
    cq = rmsnorm(cq, params["q_a_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", cq, params["q_b"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_full = jnp.einsum("bsd,dr->bsr", x, params["kv_a"].astype(dt))
    ckv = rmsnorm(ckv_full[..., :rkv], params["kv_a_norm"], cfg.norm_eps)
    k_rope = ckv_full[..., rkv:][:, :, None, :]  # (B,S,1,dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def _mla_attend(q, k, v, scale, qpos, kpos, causal, dt):
    scores = jnp.einsum("bshe,bthe->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qi = qpos[:, :, None]
        kj = kpos[:, None, :]
        scores = jnp.where((kj <= qi)[:, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthe->bshe", p.astype(dt), v)


def mla_attention(params, cfg: ArchConfig, x, positions, *,
                  causal: bool = True) -> jax.Array:
    dt = x.dtype
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, ckv, k_rope = _mla_qkv_latent(params, cfg, x, positions)
    kv = jnp.einsum("bsr,rhe->bshe", ckv, params["kv_b"].astype(dt))
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (dr,))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "heads", None)
    scale = (dn + dr) ** -0.5
    s = q.shape[1]
    if s > ATTN_CHUNK_THRESHOLD and s % ATTN_CHUNK == 0:
        nq = s // ATTN_CHUNK
        qc = jnp.moveaxis(
            q.reshape(q.shape[0], nq, ATTN_CHUNK, *q.shape[2:]), 1, 0)
        pc = jnp.moveaxis(
            positions.reshape(positions.shape[0], nq, ATTN_CHUNK), 1, 0)

        def chunk_body(_, inp):
            q_i, qpos_i = inp
            return None, _mla_attend(q_i, k, v, scale, qpos_i, positions,
                                     causal, dt)

        _, out = jax.lax.scan(chunk_body, None, (qc, pc))
        out = jnp.moveaxis(out, 0, 1).reshape(
            q.shape[0], s, q.shape[2], dv)
    else:
        out = _mla_attend(q, k, v, scale, positions, positions, causal, dt)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(dt))


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Dict:
    return dict(
        ckv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        krope=jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
        pos=jnp.full((max_len,), -1, jnp.int32),
    )


def mla_fill_cache(params, cfg, x, positions, cache) -> Dict:
    _, _, ckv, k_rope = _mla_qkv_latent(params, cfg, x, positions)
    c = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, 0, axis=1)
    r = jax.lax.dynamic_update_slice_in_dim(cache["krope"], k_rope, 0, axis=1)
    p = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions[0].astype(jnp.int32), 0, axis=0)
    return dict(ckv=c, krope=r, pos=p)


def mla_decode(params, cfg: ArchConfig, x, pos, cache) -> Tuple[jax.Array, Dict]:
    """Absorbed-matrix decode: scores live in latent space, the per-head
    key/value expansion folds into q and the output projection."""
    dt = x.dtype
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    posb = jnp.broadcast_to(pos, (x.shape[0], 1))
    q_nope, q_rope, ckv, k_rope = _mla_qkv_latent(params, cfg, x, posb)
    c = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, pos, axis=1)
    r = jax.lax.dynamic_update_slice_in_dim(cache["krope"], k_rope, pos, axis=1)
    pc = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), pos, jnp.int32), pos, axis=0)
    kv_b = params["kv_b"].astype(dt)
    # absorb k_nope expansion into q:  q_lat = q_nope @ W_k^T  (per head)
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, kv_b[..., :dn])
    scores = jnp.einsum("bshr,btr->bhst", q_lat, c,
                        preferred_element_type=jnp.float32)
    scores = scores + jnp.einsum("bshe,bte->bhst", q_rope, r,
                                 preferred_element_type=jnp.float32)
    scores = scores * ((dn + dr) ** -0.5)
    valid = (pc >= 0) & (pc <= pos)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", p.astype(dt), c)
    out = jnp.einsum("bshr,rhe->bshe", o_lat, kv_b[..., dn:])
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(dt))
    return y, dict(ckv=c, krope=r, pos=pc)
