"""repro: LGRASS — Linear Graph Spectral Sparsification (IPCC-2022) as a
production-grade JAX framework.

Layout:
    repro.core      — the paper's contribution: linear-time spectral
                      sparsification (BFS / MST / LCA / resistance / radix
                      sort / edge marking / recovery), pure JAX + host
                      recovery tail, with a python oracle for fidelity.
    repro.models    — LM-family model zoo (dense / GQA / MLA / MoE / SSM /
                      hybrid / encoder) used by the multi-pod dry-run.
    repro.kernels   — Pallas TPU kernels (flash attention, radix histogram,
                      bitmap intersection) + jnp oracles.
    repro.train     — training step / trainer with fault tolerance.
    repro.serve     — prefill / decode with KV- and SSM-state caches.
    repro.launch    — production mesh, dry-run driver, train/serve CLIs.
"""

__version__ = "0.1.0"
