"""Serving steps: batched prefill and single-token decode with persistent
caches (KV / latent / SSM state). These are the units the decode-shape
dry-run cells lower (`decode_*` / `long_*` lower serve_step, not
train_step, per the assignment).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import LM


def make_prefill_step(model: LM):
    def prefill_step(params, tokens: jax.Array, caches: Any):
        logits, caches = model.prefill(params, tokens, caches)
        return logits, caches
    return prefill_step


def make_decode_step(model: LM):
    def decode_step(params, tok: jax.Array, pos: jax.Array, caches: Any):
        logits, caches = model.decode_step(params, tok, pos, caches)
        # greedy next token (sampling handled by the server loop)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, caches
    return decode_step


def generate(model: LM, params, prompt: jax.Array, max_new: int,
             max_len: int) -> jax.Array:
    """Simple greedy generation loop (example/server use, jit per step)."""
    b, s = prompt.shape
    caches = model.init_caches(b, max_len)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model), donate_argnums=(3,))
    logits, caches = prefill(params, prompt, caches)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    outs = [tok]
    for i in range(max_new - 1):
        tok, _, caches = decode(params, tok, jnp.int32(s + i), caches)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)
