"""Batched sparsification serving: size-bucketed `GraphBatch` dispatch.

The production north star is many graphs per device dispatch, not one.
`lgrass_sparsify_batch` already amortises compile + dispatch across a
padded batch — since the recovery refactor the whole pipeline (phase 1
AND the Algorithm-6 replay) is one fused device program, so a bucket is
served by exactly one dispatch with no host round-trip between phases.
This module adds the traffic-facing policy:

  * **bucketing** — a request stream contains arbitrary (n, L) sizes,
    and every distinct padded shape is a fresh XLA compile. We round the
    pad targets up to powers of two (with a small floor), so the number
    of compiled programs is logarithmic in the size range instead of
    linear in the number of distinct sizes seen. The recovery accept
    buffer (`b_cap`) is bucketed the same way, keyed off the bucket's
    default budget, so default-budget traffic reuses one program per
    shape bucket.
  * **chunking** — buckets are dispatched in batches of at most
    `max_batch_size` graphs to bound device memory.
  * **batch-dim bucketing** — the leading batch axis is itself a
    compiled dimension, so each chunk is padded up to a power of two
    with trivial placeholder graphs (dropped from the results); chunk
    sizes 5, 7, 12 share the B=8/8/16 programs instead of compiling
    three times.
  * **schedule policy** — the phase-1 marking engine is a per-service
    config (`schedule="chunked"` by default) and its block size is
    resolved *per bucket* from the padded edge count
    (`core.pow2.auto_chunk`), so every graph in a bucket shares one
    compiled block size and `warmup` compiles exactly the programs
    traffic will request.
  * **BFS-engine policy** — the traversal engine (`bfs_engine=
    "doubling"` by default: hop-doubling graph BFS + Euler-tour tree
    rooting, O(log n) rounds on diameter-bound inputs) is a compiled-
    program key like the block size, resolved per bucket through one
    hook (`_bfs_engine`) that both the request path and `warmup` use.
  * **warmup** — `warmup(sizes)` pre-compiles the bucket programs for
    anticipated request shapes off the request path; compile counts and
    wall-clock are surfaced in `ServiceStats`.

Results come back in request order and are bit-identical to per-graph
`lgrass_sparsify` (the batch path guarantees this; see
tests/test_batch.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.baseline import default_budget
from repro.core.graph import Graph, GraphBatch
from repro.core.pow2 import auto_chunk, next_pow2
from repro.core.sparsify import (
    SparsifyResult,
    _bucket_b_cap,
    lgrass_sparsify_batch,
)


def _placeholder_graph() -> Graph:
    """Smallest valid graph; pads the batch axis (results discarded)."""
    return Graph(n=2, u=np.array([0], np.int32), v=np.array([1], np.int32),
                 w=np.array([1.0], np.float32))


@dataclasses.dataclass
class ServiceStats:
    n_graphs: int = 0
    n_dispatches: int = 0
    n_padded_edge_slots: int = 0   # total L_max over dispatched rows
    n_real_edge_slots: int = 0
    bucket_counts: Dict[Tuple[int, int], int] = dataclasses.field(
        default_factory=dict
    )
    n_warmup_dispatches: int = 0   # compiles triggered off the request path
    warmup_seconds: float = 0.0

    @property
    def padding_overhead(self) -> float:
        """Fraction of dispatched edge slots that were padding."""
        if self.n_padded_edge_slots == 0:
            return 0.0
        return 1.0 - self.n_real_edge_slots / self.n_padded_edge_slots


class SparsifyService:
    """Sparsify request batches with a bounded set of compiled shapes.

    >>> svc = SparsifyService()
    >>> svc.warmup([(100, 300)])             # optional: compile off-path
    >>> results = svc.sparsify(list_of_graphs)   # request order preserved
    """

    def __init__(
        self,
        k_cap: int = 32,
        parallel: bool = True,
        max_batch_size: int = 64,
        min_n_bucket: int = 16,
        min_L_bucket: int = 32,
        recovery: str = "device",
        schedule: str = "chunked",
        p1_chunk: Optional[int] = None,
        bfs_engine: str = "doubling",
    ):
        self.k_cap = k_cap
        self.parallel = parallel
        self.max_batch_size = max_batch_size
        self.min_n_bucket = min_n_bucket
        self.min_L_bucket = min_L_bucket
        self.recovery = recovery
        self.schedule = schedule
        self.p1_chunk = p1_chunk
        self.bfs_engine = bfs_engine
        self.stats = ServiceStats()

    def _p1_chunk(self, L_bucket: int) -> Optional[int]:
        """Per-bucket phase-1 block size policy.

        The scheduler's auto policy (`core.pow2.auto_chunk`) is a
        function of the *padded* edge count, so it is resolved here from
        the bucket — every graph in a bucket shares one compiled block
        size, and `warmup` compiles exactly the program traffic will
        request. An explicit `p1_chunk` pins all buckets instead.
        """
        if self.schedule != "chunked":
            return None
        if self.p1_chunk is not None:
            return self.p1_chunk
        return auto_chunk(L_bucket)

    def _bfs_engine(self, n_bucket: int) -> str:
        """Per-bucket BFS-engine policy.

        The engine is a compiled-program key, so — exactly like the
        phase-1 block size — it is resolved through this one hook from
        the bucket, and `warmup` resolves through the same hook: warmed
        programs are the ones traffic requests. The default policy is
        uniform ("doubling" everywhere: it is never more loop rounds
        than level-sync and collapses diameter-bound buckets to
        O(log n)); subclasses with measured per-size preferences can
        override on `n_bucket`.
        """
        return self.bfs_engine

    def _bucket(self, n: int, L: int) -> Tuple[int, int]:
        """The bucketing policy, from raw sizes — the single source both
        the request path (`bucket_key`) and `warmup` resolve through, so
        warmed programs are exactly the ones traffic requests."""
        return (
            max(next_pow2(int(n)), self.min_n_bucket),
            max(next_pow2(int(L)), self.min_L_bucket),
        )

    def bucket_key(self, g: Graph) -> Tuple[int, int]:
        """(n_bucket, L_bucket): pad targets rounded up to powers of two."""
        return self._bucket(g.n, g.m)

    def _b_cap(self, n_bucket: int, budgets: Sequence[int]) -> int:
        """Accept-buffer bucket for a chunk.

        Keyed off the bucket's own default budget so that default-budget
        traffic (every graph's budget <= default_budget(n_bucket)) maps
        to ONE compiled b_cap per shape bucket — which is also what
        `warmup` compiles. Larger explicit budgets widen it.
        """
        return _bucket_b_cap(list(budgets) + [default_budget(n_bucket)])

    def sparsify(
        self,
        graphs: Sequence[Graph],
        budget: Optional[object] = None,
    ) -> List[SparsifyResult]:
        """Sparsify `graphs`, returning results in request order.

        budget: None (per-graph default), an int for all graphs, or a
        sequence with one budget per graph.
        """
        graphs = list(graphs)
        # same scalar/sequence normalization as lgrass_sparsify_batch
        if budget is None or np.ndim(budget) == 0:
            budgets = [budget] * len(graphs)
        else:
            budgets = list(budget)
            if len(budgets) != len(graphs):
                raise ValueError("one budget per graph required")

        by_bucket: Dict[Tuple[int, int], List[int]] = {}
        for i, g in enumerate(graphs):
            by_bucket.setdefault(self.bucket_key(g), []).append(i)

        results: List[Optional[SparsifyResult]] = [None] * len(graphs)
        for key in sorted(by_bucket):
            idxs = by_bucket[key]
            n_bucket, L_bucket = key
            self.stats.bucket_counts[key] = (
                self.stats.bucket_counts.get(key, 0) + len(idxs)
            )
            for lo in range(0, len(idxs), self.max_batch_size):
                chunk = idxs[lo: lo + self.max_batch_size]
                # pad the batch axis to a pow2 so chunk sizes share programs
                B_pad = next_pow2(len(chunk))
                n_fill = B_pad - len(chunk)
                batch = GraphBatch.from_graphs(
                    [graphs[i] for i in chunk]
                    + [_placeholder_graph()] * n_fill,
                    n_max=n_bucket,
                    L_max=L_bucket,
                )
                # resolve None budgets ONCE; the callee receives concrete
                # values, so b_cap sizing and dispatch can't disagree
                resolved = [
                    default_budget(graphs[i].n) if budgets[i] is None
                    else int(budgets[i])
                    for i in chunk
                ]
                out = lgrass_sparsify_batch(
                    batch,
                    budget=resolved + [None] * n_fill,
                    k_cap=self.k_cap, parallel=self.parallel,
                    recovery=self.recovery,
                    b_cap=self._b_cap(n_bucket, resolved),
                    schedule=self.schedule,
                    p1_chunk=self._p1_chunk(L_bucket),
                    bfs_engine=self._bfs_engine(n_bucket),
                )
                for i, r in zip(chunk, out):  # placeholder tail dropped
                    results[i] = r
                self.stats.n_dispatches += 1
                self.stats.n_graphs += len(chunk)
                self.stats.n_padded_edge_slots += L_bucket * B_pad
                self.stats.n_real_edge_slots += sum(
                    graphs[i].m for i in chunk
                )
        return results  # type: ignore[return-value]

    def warmup(
        self,
        sizes: Iterable[Tuple[int, int]],
        batch_sizes: Sequence[int] = (1,),
    ) -> int:
        """Pre-compile bucket programs for anticipated request shapes.

        sizes: (n, L) pairs of representative requests — each is rounded
        to its bucket exactly as `sparsify` would. batch_sizes: chunk
        sizes to warm (each padded to a pow2 batch axis, like the request
        path). Dispatches run on placeholder graphs whose results are
        discarded; XLA's compile cache then serves real traffic without
        on-path compilation. Returns the number of warmup dispatches;
        `stats.n_warmup_dispatches` / `stats.warmup_seconds` accumulate.
        """
        t0 = time.perf_counter()
        done = set()
        n_dispatched = 0
        for (n, L) in sizes:
            n_bucket, L_bucket = self._bucket(n, L)
            b_cap = self._b_cap(n_bucket, [])
            for B in batch_sizes:
                B_pad = next_pow2(int(B))
                sig = (n_bucket, L_bucket, B_pad, b_cap)
                if sig in done:
                    continue
                done.add(sig)
                batch = GraphBatch.from_graphs(
                    [_placeholder_graph()] * B_pad,
                    n_max=n_bucket, L_max=L_bucket,
                )
                lgrass_sparsify_batch(
                    batch, budget=None, k_cap=self.k_cap,
                    parallel=self.parallel, recovery=self.recovery,
                    b_cap=b_cap,
                    schedule=self.schedule,
                    p1_chunk=self._p1_chunk(L_bucket),
                    bfs_engine=self._bfs_engine(n_bucket),
                )
                n_dispatched += 1
        self.stats.n_warmup_dispatches += n_dispatched
        self.stats.warmup_seconds += time.perf_counter() - t0
        return n_dispatched
