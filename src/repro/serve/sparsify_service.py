"""Batched sparsification serving: size-bucketed `GraphBatch` dispatch.

The production north star is many graphs per device dispatch, not one.
`lgrass_sparsify_batch` already amortises compile + dispatch across a
padded batch; this module adds the traffic-facing policy:

  * **bucketing** — a request stream contains arbitrary (n, L) sizes,
    and every distinct padded shape is a fresh XLA compile. We round the
    pad targets up to powers of two (with a small floor), so the number
    of compiled programs is logarithmic in the size range instead of
    linear in the number of distinct sizes seen.
  * **chunking** — buckets are dispatched in batches of at most
    `max_batch_size` graphs to bound device memory.
  * **batch-dim bucketing** — the leading batch axis is itself a
    compiled dimension, so each chunk is padded up to a power of two
    with trivial placeholder graphs (dropped from the results); chunk
    sizes 5, 7, 12 share the B=8/8/16 programs instead of compiling
    three times.

Results come back in request order and are bit-identical to per-graph
`lgrass_sparsify` (the batch path guarantees this; see
tests/test_batch.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph, GraphBatch
from repro.core.sparsify import SparsifyResult, lgrass_sparsify_batch


def _placeholder_graph() -> Graph:
    """Smallest valid graph; pads the batch axis (results discarded)."""
    return Graph(n=2, u=np.array([0], np.int32), v=np.array([1], np.int32),
                 w=np.array([1.0], np.float32))


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (x >= 1)."""
    p = 1
    while p < x:
        p <<= 1
    return p


@dataclasses.dataclass
class ServiceStats:
    n_graphs: int = 0
    n_dispatches: int = 0
    n_padded_edge_slots: int = 0   # total L_max over dispatched rows
    n_real_edge_slots: int = 0
    bucket_counts: Dict[Tuple[int, int], int] = dataclasses.field(
        default_factory=dict
    )

    @property
    def padding_overhead(self) -> float:
        """Fraction of dispatched edge slots that were padding."""
        if self.n_padded_edge_slots == 0:
            return 0.0
        return 1.0 - self.n_real_edge_slots / self.n_padded_edge_slots


class SparsifyService:
    """Sparsify request batches with a bounded set of compiled shapes.

    >>> svc = SparsifyService()
    >>> results = svc.sparsify(list_of_graphs)   # request order preserved
    """

    def __init__(
        self,
        k_cap: int = 32,
        parallel: bool = True,
        max_batch_size: int = 64,
        min_n_bucket: int = 16,
        min_L_bucket: int = 32,
    ):
        self.k_cap = k_cap
        self.parallel = parallel
        self.max_batch_size = max_batch_size
        self.min_n_bucket = min_n_bucket
        self.min_L_bucket = min_L_bucket
        self.stats = ServiceStats()

    def bucket_key(self, g: Graph) -> Tuple[int, int]:
        """(n_bucket, L_bucket): pad targets rounded up to powers of two."""
        return (
            max(next_pow2(g.n), self.min_n_bucket),
            max(next_pow2(g.m), self.min_L_bucket),
        )

    def sparsify(
        self,
        graphs: Sequence[Graph],
        budget: Optional[object] = None,
    ) -> List[SparsifyResult]:
        """Sparsify `graphs`, returning results in request order.

        budget: None (per-graph default), an int for all graphs, or a
        sequence with one budget per graph.
        """
        graphs = list(graphs)
        # same scalar/sequence normalization as lgrass_sparsify_batch
        if budget is None or np.ndim(budget) == 0:
            budgets = [budget] * len(graphs)
        else:
            budgets = list(budget)
            if len(budgets) != len(graphs):
                raise ValueError("one budget per graph required")

        by_bucket: Dict[Tuple[int, int], List[int]] = {}
        for i, g in enumerate(graphs):
            by_bucket.setdefault(self.bucket_key(g), []).append(i)

        results: List[Optional[SparsifyResult]] = [None] * len(graphs)
        for key in sorted(by_bucket):
            idxs = by_bucket[key]
            n_bucket, L_bucket = key
            self.stats.bucket_counts[key] = (
                self.stats.bucket_counts.get(key, 0) + len(idxs)
            )
            for lo in range(0, len(idxs), self.max_batch_size):
                chunk = idxs[lo: lo + self.max_batch_size]
                # pad the batch axis to a pow2 so chunk sizes share programs
                B_pad = next_pow2(len(chunk))
                n_fill = B_pad - len(chunk)
                batch = GraphBatch.from_graphs(
                    [graphs[i] for i in chunk]
                    + [_placeholder_graph()] * n_fill,
                    n_max=n_bucket,
                    L_max=L_bucket,
                )
                out = lgrass_sparsify_batch(
                    batch,
                    budget=[budgets[i] for i in chunk] + [None] * n_fill,
                    k_cap=self.k_cap, parallel=self.parallel,
                )
                for i, r in zip(chunk, out):  # placeholder tail dropped
                    results[i] = r
                self.stats.n_dispatches += 1
                self.stats.n_graphs += len(chunk)
                self.stats.n_padded_edge_slots += L_bucket * B_pad
                self.stats.n_real_edge_slots += sum(
                    graphs[i].m for i in chunk
                )
        return results  # type: ignore[return-value]
