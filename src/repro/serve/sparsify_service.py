"""Batched sparsification serving: size-bucketed `GraphBatch` dispatch.

The production north star is many graphs per device dispatch, not one.
`lgrass_sparsify_batch` already amortises compile + dispatch across a
padded batch — since the recovery refactor the whole pipeline (phase 1
AND the Algorithm-6 replay) is one fused device program, so a bucket is
served by exactly one dispatch with no host round-trip between phases.
This module adds the traffic-facing policy:

  * **bucketing** — a request stream contains arbitrary (n, L) sizes,
    and every distinct padded shape is a fresh XLA compile. We round the
    pad targets up to powers of two (with a small floor), so the number
    of compiled programs is logarithmic in the size range instead of
    linear in the number of distinct sizes seen. The recovery accept
    buffer (`b_cap`) is bucketed the same way, keyed off the bucket's
    default budget, so default-budget traffic reuses one program per
    shape bucket.
  * **chunking** — buckets are dispatched in batches of at most
    `max_batch_size` graphs to bound device memory.
  * **batch-dim bucketing** — the leading batch axis is itself a
    compiled dimension, so each chunk is padded up to a power of two
    with trivial placeholder graphs (dropped from the results); chunk
    sizes 5, 7, 12 share the B=8/8/16 programs instead of compiling
    three times.
  * **schedule policy** — the phase-1 marking engine is a per-service
    config (`schedule="chunked"` by default) and its block size is
    resolved *per bucket* from the padded edge count
    (`core.pow2.auto_chunk`), so every graph in a bucket shares one
    compiled block size and `warmup` compiles exactly the programs
    traffic will request.
  * **BFS-engine policy** — the traversal engine (`bfs_engine=
    "doubling"` by default: hop-doubling graph BFS + Euler-tour tree
    rooting, O(log n) rounds on diameter-bound inputs) is a compiled-
    program key like the block size, resolved per bucket through one
    hook (`_bfs_engine`) that both the request path and `warmup` use.
  * **warmup** — `warmup(sizes)` pre-compiles the bucket programs for
    anticipated request shapes off the request path; compile counts and
    wall-clock are surfaced in `ServiceStats`.

The serving plane on top of the bucketing (PR 6):

  * **async dispatch** (`async_dispatch=True`) — JAX dispatch is
    already asynchronous; the sync path wastes that by calling
    `jax.device_get` after every chunk. The async path enqueues EVERY
    chunk's device program first, holding the per-chunk `jax.Array`
    dicts, and only then drains them in request order — host result
    assembly for chunk k overlaps device compute of chunks k+1..K.
  * **buffer donation** (`donate=True`) — chunks dispatch through
    `lgrass_device_batched_donated` (`donate_argnums` on the padded
    u/v/w/edge_valid/budget arrays, exactly as `serve/serve_step.py`
    donates decode caches), so XLA reuses the request's input buffers
    for its outputs instead of allocating fresh device memory per call.
    Host-side, a per-bucket pinned staging pool reuses the padded numpy
    arrays across requests (the device transfer is a forced copy, so
    refilling the pool can never race a donated in-flight buffer).
  * **batch-axis sharding** (`mesh=...`) — `lgrass_device_batched` is
    embarrassingly parallel over its leading (graph) axis, so a chunk's
    batch axis is sharded across the mesh
    (`core.distributed.shard_batch_leading`, built on the
    `repro.compat` shims); one pod serves one mega-bucket. The batch
    pad target rounds up to a multiple of the mesh size so every shard
    gets equal rows.
  * **on-path compile accounting** — every dispatch signature
    (n_bucket, L_bucket, B_pad, b_cap) is checked against the set
    `warmup` compiled; signatures first seen on the request path count
    in `ServiceStats.n_on_path_compiles`. The policy: a request whose
    explicit budget exceeds `default_budget(n_bucket)` widens `b_cap`
    to the next pow2 bucket — a program `warmup(sizes)` alone never
    compiled. Pass those budgets to `warmup(..., budgets=[...])` to
    pre-compile the wide-budget programs; after that, steady traffic
    can assert `stats.n_on_path_compiles == 0`.

Results come back in request order and are bit-identical to per-graph
`lgrass_sparsify` under every mode — sync, async, donated, sharded
(tests/test_batch.py, tests/test_service_plane.py).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baseline import default_budget
from repro.core.distributed import mesh_size, shard_batch_leading
from repro.core.graph import (PAD_ENDPOINT, PAD_WEIGHT, Graph, GraphBatch,
                              trivial_graph)
from repro.core.pow2 import auto_chunk, next_pow2
from repro.core.sparsify import (
    SparsifyResult,
    _bucket_b_cap,
    _result_from_device,
    lgrass_device_batched,
    lgrass_device_batched_donated,
)


def _placeholder_graph() -> Graph:
    """Smallest valid graph; pads the batch axis (results discarded).

    Must fit EVERY bucket — the (n=1, m=0) trivial graph does; the old
    (n=2, m=1) filler crashed buckets smaller than (2, 1)."""
    return trivial_graph()


@dataclasses.dataclass
class ServiceStats:
    n_graphs: int = 0
    n_dispatches: int = 0
    n_padded_edge_slots: int = 0   # total L_bucket * B_pad over dispatches
    n_real_edge_slots: int = 0     # real edges of real (requested) graphs
    # the two distinct kinds of padding a dispatch carries:
    n_batch_pad_edge_slots: int = 0  # placeholder rows: L_bucket * n_fill
    n_shape_pad_edge_slots: int = 0  # real rows' tail: L_bucket*B_real - m
    bucket_counts: Dict[Tuple[int, int], int] = dataclasses.field(
        default_factory=dict
    )
    n_warmup_dispatches: int = 0   # compiles triggered off the request path
    warmup_seconds: float = 0.0
    # dispatch signatures (n_bucket, L_bucket, B_pad, b_cap) first seen on
    # the request path — i.e. programs warmup never compiled. Counted once
    # per signature (XLA caches the compile); see the module docstring for
    # the b_cap-widening policy that makes this nonzero.
    n_on_path_compiles: int = 0

    @property
    def padding_overhead(self) -> float:
        """Fraction of dispatched edge slots that were padding (both
        kinds: batch-axis placeholder rows AND real rows' shape tail)."""
        if self.n_padded_edge_slots == 0:
            return 0.0
        return (self.n_batch_pad_edge_slots + self.n_shape_pad_edge_slots
                ) / self.n_padded_edge_slots

    @property
    def batch_pad_overhead(self) -> float:
        """Fraction of dispatched edge slots burned on placeholder rows
        (the pow2 batch-axis fill). Tune with max_batch_size / warmup
        batch_sizes."""
        if self.n_padded_edge_slots == 0:
            return 0.0
        return self.n_batch_pad_edge_slots / self.n_padded_edge_slots

    @property
    def shape_pad_overhead(self) -> float:
        """Fraction of dispatched edge slots burned padding real graphs
        up to their (n_bucket, L_bucket) shape. Tune with the bucket
        floors."""
        if self.n_padded_edge_slots == 0:
            return 0.0
        return self.n_shape_pad_edge_slots / self.n_padded_edge_slots


class _StagingPool:
    """Per-(B_pad, L_bucket) pinned host buffers for padded chunks.

    Steady-state traffic refills pooled numpy arrays instead of
    allocating a fresh `GraphBatch` per chunk. Reuse is guarded by a
    FENCE: host->device transfers on this backend are themselves
    asynchronous (the dispatch reads the host buffer when the program
    actually runs — observed on CPU PJRT, where refilling a live
    staging buffer corrupted in-flight async chunks), and blocking on
    the transfer is no better (it queues behind pending compute, which
    would serialize the whole async plane). So each buffer set carries
    the `jax.Array` output of the dispatch that last used it: outputs
    ready => the program ran => its input transfers are consumed => the
    buffers are reusable. `acquire` picks a fenced-out set without
    blocking, growing the pool to the max number of in-flight chunks
    per shape (steady state allocates nothing).
    """

    def __init__(self):
        # key -> list of [bufs_tuple, fence]; fence None = free now
        self._sets: Dict[Tuple[int, int], List[list]] = {}

    def acquire(self, B_pad: int, L_bucket: int) -> list:
        """A [bufs, fence] entry whose buffers are provably not read by
        any in-flight dispatch; never blocks (allocates when all sets
        are fenced). Caller must re-arm entry[1] after dispatching."""
        sets = self._sets.setdefault((B_pad, L_bucket), [])
        for entry in sets:
            fence = entry[1]
            if fence is None or bool(fence.is_ready()):
                entry[1] = None
                return entry
        entry = [
            (
                np.empty((B_pad, L_bucket), np.int32),
                np.empty((B_pad, L_bucket), np.int32),
                np.empty((B_pad, L_bucket), np.float32),
                np.empty((B_pad, L_bucket), bool),
                np.empty((B_pad,), np.int32),
            ),
            None,
        ]
        sets.append(entry)
        return entry

    @property
    def n_buffer_sets(self) -> int:
        return sum(len(v) for v in self._sets.values())

    @staticmethod
    def fill(bufs, graphs: Sequence[Graph]):
        """Pad-fill (u, v, w, edge_valid, budget) staging arrays with the
        leading len(graphs) rows holding the real graphs and the tail
        rows left as all-padding placeholder rows."""
        u, v, w, ev, bb = bufs
        u.fill(PAD_ENDPOINT)
        v.fill(PAD_ENDPOINT)
        w.fill(PAD_WEIGHT)
        ev.fill(False)
        bb.fill(1)  # placeholder rows get the trivial budget
        for i, g in enumerate(graphs):
            m = g.m
            u[i, :m] = g.u
            v[i, :m] = g.v
            w[i, :m] = g.w
            ev[i, :m] = True
        return bufs


@dataclasses.dataclass(frozen=True)
class ProgramSpec:
    """One compiled-program signature of the service, in auditable form:
    the jit callable, abstract argument shapes, and the static kwargs —
    exactly what `_dispatch` would run for that signature. Consumed by
    the static auditor (`repro.analysis.jaxpr_audit.audit_service`),
    which traces fn over args and walks the jaxpr."""
    name: str
    signature: Tuple[int, int, int, int]   # (n_bucket, L_bucket, B_pad, b_cap)
    fn: object                             # the jit-wrapped callable
    args: tuple                            # jax.ShapeDtypeStruct per array arg
    static_kwargs: dict


@dataclasses.dataclass
class _PendingChunk:
    """One dispatched chunk awaiting drain: the device output dict plus
    everything needed to scatter rows back into request order."""
    idxs: List[int]          # request indices of the real rows
    Ls: List[int]            # per-row true edge counts (result slicing)
    device: dict             # jax.Array outputs of the fused program


class SparsifyService:
    """Sparsify request batches with a bounded set of compiled shapes.

    >>> svc = SparsifyService(async_dispatch=True, donate=True)
    >>> svc.warmup([(100, 300)])             # optional: compile off-path
    >>> results = svc.sparsify(list_of_graphs)   # request order preserved

    async_dispatch: enqueue every chunk's device program before draining
    any result (overlaps host assembly with device compute). donate:
    dispatch through the donated program + pinned staging pool. mesh:
    shard the batch axis of each chunk across the mesh (requires
    recovery="device", as do the other serving-plane modes).
    """

    def __init__(
        self,
        k_cap: int = 32,
        parallel: bool = True,
        max_batch_size: int = 64,
        min_n_bucket: int = 16,
        min_L_bucket: int = 32,
        recovery: str = "device",
        schedule: str = "chunked",
        p1_chunk: Optional[int] = None,
        bfs_engine: str = "doubling",
        async_dispatch: bool = False,
        donate: bool = False,
        mesh=None,
    ):
        self.k_cap = k_cap
        self.parallel = parallel
        self.max_batch_size = max_batch_size
        self.min_n_bucket = min_n_bucket
        self.min_L_bucket = min_L_bucket
        self.recovery = recovery
        self.schedule = schedule
        self.p1_chunk = p1_chunk
        self.bfs_engine = bfs_engine
        self.async_dispatch = async_dispatch
        self.donate = donate
        self.mesh = mesh
        if recovery == "device":
            pass
        elif recovery == "host":
            if async_dispatch or donate or mesh is not None:
                raise ValueError(
                    "async_dispatch/donate/mesh require recovery='device' "
                    "(the host oracle tail blocks per chunk by design)"
                )
        else:
            raise ValueError(f"unknown recovery mode {recovery!r}")
        self.stats = ServiceStats()
        self._pool = _StagingPool()
        self._warmed: Set[Tuple[int, int, int, int]] = set()
        self._seen: Set[Tuple[int, int, int, int]] = set()

    # ---------------------------------------------------------- policies

    def _p1_chunk(self, L_bucket: int) -> Optional[int]:
        """Per-bucket phase-1 block size policy.

        The scheduler's auto policy (`core.pow2.auto_chunk`) is a
        function of the *padded* edge count, so it is resolved here from
        the bucket — every graph in a bucket shares one compiled block
        size, and `warmup` compiles exactly the program traffic will
        request. An explicit `p1_chunk` pins all buckets instead.
        """
        if self.schedule != "chunked":
            return None
        if self.p1_chunk is not None:
            return self.p1_chunk
        return auto_chunk(L_bucket)

    def _bfs_engine(self, n_bucket: int) -> str:
        """Per-bucket BFS-engine policy.

        The engine is a compiled-program key, so — exactly like the
        phase-1 block size — it is resolved through this one hook from
        the bucket, and `warmup` resolves through the same hook: warmed
        programs are the ones traffic requests. The default policy is
        uniform ("doubling" everywhere: it is never more loop rounds
        than level-sync and collapses diameter-bound buckets to
        O(log n)); subclasses with measured per-size preferences can
        override on `n_bucket`.
        """
        return self.bfs_engine

    def _bucket(self, n: int, L: int) -> Tuple[int, int]:
        """The bucketing policy, from raw sizes — the single source both
        the request path (`bucket_key`) and `warmup` resolve through, so
        warmed programs are exactly the ones traffic requests."""
        return (
            max(next_pow2(int(n)), self.min_n_bucket),
            max(next_pow2(int(L)), self.min_L_bucket),
        )

    def bucket_key(self, g: Graph) -> Tuple[int, int]:
        """(n_bucket, L_bucket): pad targets rounded up to powers of two.

        Well-defined for edgeless graphs too: next_pow2 floors at 1, so
        a (n=1, m=0) request lands in the smallest bucket."""
        return self._bucket(g.n, g.m)

    def _b_cap(self, n_bucket: int, budgets: Sequence[int]) -> int:
        """Accept-buffer bucket for a chunk.

        Keyed off the bucket's own default budget so that default-budget
        traffic (every graph's budget <= default_budget(n_bucket)) maps
        to ONE compiled b_cap per shape bucket — which is also what
        `warmup` compiles. Larger explicit budgets widen it (and land a
        fresh dispatch signature: see n_on_path_compiles).
        """
        return _bucket_b_cap(list(budgets) + [default_budget(n_bucket)])

    def _program_kwargs(self, n_bucket: int, L_bucket: int,
                        b_cap: int) -> dict:
        """The static kwargs of the compiled program for one dispatch
        signature — the SINGLE definition `_dispatch`, `warmup` (via
        `_dispatch`) and the static auditor (`program_specs`) share, so
        what the auditor proves is exactly what traffic runs."""
        return dict(
            n=n_bucket,
            k_cap=self.k_cap,
            parallel=self.parallel,
            lift_levels=None,
            b_cap=b_cap,
            use_tree_kernel=False,
            chunk=32,
            schedule=self.schedule,
            p1_chunk=self._p1_chunk(L_bucket),
            use_euler_lca=True,
            bfs_engine=self._bfs_engine(n_bucket),
        )

    @property
    def dispatch_fn(self):
        """The ONE jit callable every device chunk dispatches through
        for this service's mode (donated or plain)."""
        return (lgrass_device_batched_donated if self.donate
                else lgrass_device_batched)

    def compiled_signatures(self) -> List[Tuple[int, int, int, int]]:
        """Every dispatch signature (n_bucket, L_bucket, B_pad, b_cap)
        this service has compiled — warmed and request-path alike."""
        return sorted(self._warmed | self._seen)

    def program_specs(
        self,
        sizes: Optional[Iterable[Tuple[int, int]]] = None,
        batch_sizes: Sequence[int] = (1,),
        budgets: Sequence[int] = (),
    ) -> List[ProgramSpec]:
        """`ProgramSpec`s for the compiled-program set, WITHOUT
        compiling or dispatching anything — pure bucketing math, so the
        static auditor can cover the warmed signature set off-device.

        sizes=None audits the signatures already compiled
        (`compiled_signatures`); otherwise (n, L) pairs are resolved
        through the same bucketing/b_cap/batch-pad policies `warmup`
        and the request path use.
        """
        if sizes is None:
            sigs = self.compiled_signatures()
        else:
            sigset = set()
            for (n, L) in sizes:
                n_bucket, L_bucket = self._bucket(n, L)
                b_cap = self._b_cap(n_bucket, list(budgets))
                for B in batch_sizes:
                    sigset.add((n_bucket, L_bucket, self._pad_batch(int(B)),
                                b_cap))
            sigs = sorted(sigset)
        mode = ("donated" if self.donate else
                "sharded" if self.mesh is not None else "plain")
        specs = []
        for sig in sigs:
            n_bucket, L_bucket, B_pad, b_cap = sig
            args = (
                jax.ShapeDtypeStruct((B_pad, L_bucket), jnp.int32),
                jax.ShapeDtypeStruct((B_pad, L_bucket), jnp.int32),
                jax.ShapeDtypeStruct((B_pad, L_bucket), jnp.float32),
                jax.ShapeDtypeStruct((B_pad, L_bucket), jnp.bool_),
                jax.ShapeDtypeStruct((B_pad,), jnp.int32),
            )
            specs.append(ProgramSpec(
                name=f"lgrass_device_batched[{mode}]"
                     f"(n={n_bucket},L={L_bucket},B={B_pad},b_cap={b_cap})",
                signature=sig,
                fn=self.dispatch_fn,
                args=args,
                static_kwargs=self._program_kwargs(n_bucket, L_bucket,
                                                   b_cap),
            ))
        return specs

    def _pad_batch(self, n_chunk: int) -> int:
        """Batch-axis pad target for a chunk of `n_chunk` graphs: the
        next power of two, rounded up to whole mesh multiples when
        sharding so every shard gets equal rows."""
        if self.mesh is not None:
            ms = mesh_size(self.mesh)
            return ms * next_pow2(-(-int(n_chunk) // ms))
        return next_pow2(int(n_chunk))

    # ---------------------------------------------------------- dispatch

    def _dispatch(
        self,
        graphs: Sequence[Graph],
        budgets: Sequence[int],
        n_bucket: int,
        L_bucket: int,
        B_pad: int,
        b_cap: int,
    ) -> dict:
        """Enqueue ONE padded chunk on the device; returns the device
        output dict WITHOUT blocking (JAX dispatch is async). The single
        funnel for the request path AND warmup, so the donated/sharded
        program variants are exactly the ones warmup compiles."""
        entry = self._pool.acquire(B_pad, L_bucket)
        u, v, w, ev, bb = self._pool.fill(entry[0], graphs)
        bb[: len(budgets)] = np.asarray(budgets, np.int32)
        # jnp.array (copy=True) — NOT asarray/device_put, which zero-copy
        # aligned host buffers on CPU PJRT and would alias the staging
        # pool into live device arrays (see _StagingPool)
        arrs = (jnp.array(u), jnp.array(v), jnp.array(w),
                jnp.array(ev), jnp.array(bb))
        if self.mesh is not None:
            arrs = shard_batch_leading(arrs, self.mesh)
        with warnings.catch_warnings():
            # only edge_valid/budget can alias a same-shape output; XLA's
            # "donated buffers were not usable" note for u/v/w is expected
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            d = self.dispatch_fn(
                *arrs,
                **self._program_kwargs(n_bucket, L_bucket, b_cap),
            )
        # re-arm the fence: these outputs ready <=> this dispatch ran and
        # consumed its (async) input transfers => buffers reusable
        entry[1] = d["n_accepted"]
        return d

    @staticmethod
    def _drain(pending: _PendingChunk, results: List[Optional[SparsifyResult]]):
        """Block on one chunk's device outputs and scatter its rows into
        `results` at their request indices (placeholder tail dropped)."""
        host = jax.device_get(pending.device)
        for row, (i, L) in enumerate(zip(pending.idxs, pending.Ls)):
            results[i] = _result_from_device(host, row, L)

    # ---------------------------------------------------------- serving

    def sparsify(
        self,
        graphs: Sequence[Graph],
        budget: Optional[object] = None,
    ) -> List[SparsifyResult]:
        """Sparsify `graphs`, returning results in request order.

        budget: None (per-graph default), an int for all graphs, or a
        sequence with one budget per graph.
        """
        graphs = list(graphs)
        # same scalar/sequence normalization as lgrass_sparsify_batch
        if budget is None or np.ndim(budget) == 0:
            budgets = [budget] * len(graphs)
        else:
            budgets = list(budget)
            if len(budgets) != len(graphs):
                raise ValueError("one budget per graph required")

        by_bucket: Dict[Tuple[int, int], List[int]] = {}
        for i, g in enumerate(graphs):
            by_bucket.setdefault(self.bucket_key(g), []).append(i)

        results: List[Optional[SparsifyResult]] = [None] * len(graphs)
        pending: List[_PendingChunk] = []
        for key in sorted(by_bucket):
            idxs = by_bucket[key]
            n_bucket, L_bucket = key
            self.stats.bucket_counts[key] = (
                self.stats.bucket_counts.get(key, 0) + len(idxs)
            )
            for lo in range(0, len(idxs), self.max_batch_size):
                chunk = idxs[lo: lo + self.max_batch_size]
                B_pad = self._pad_batch(len(chunk))
                # resolve None budgets ONCE; the program receives concrete
                # values, so b_cap sizing and dispatch can't disagree
                resolved = [
                    default_budget(graphs[i].n) if budgets[i] is None
                    else int(budgets[i])
                    for i in chunk
                ]
                b_cap = self._b_cap(n_bucket, resolved)
                sig = (n_bucket, L_bucket, B_pad, b_cap)
                if sig not in self._warmed and sig not in self._seen:
                    self.stats.n_on_path_compiles += 1
                self._seen.add(sig)
                if self.recovery == "host":
                    self._sparsify_host_chunk(
                        graphs, chunk, resolved, n_bucket, L_bucket, B_pad,
                        b_cap, results)
                else:
                    d = self._dispatch(
                        [graphs[i] for i in chunk], resolved,
                        n_bucket, L_bucket, B_pad, b_cap)
                    item = _PendingChunk(
                        idxs=chunk, Ls=[graphs[i].m for i in chunk], device=d)
                    if self.async_dispatch:
                        pending.append(item)   # drain after ALL dispatches
                    else:
                        self._drain(item, results)
                n_fill = B_pad - len(chunk)
                n_real = sum(graphs[i].m for i in chunk)
                self.stats.n_dispatches += 1
                self.stats.n_graphs += len(chunk)
                self.stats.n_padded_edge_slots += L_bucket * B_pad
                self.stats.n_real_edge_slots += n_real
                self.stats.n_batch_pad_edge_slots += L_bucket * n_fill
                self.stats.n_shape_pad_edge_slots += (
                    L_bucket * len(chunk) - n_real
                )
        for item in pending:
            self._drain(item, results)
        return results  # type: ignore[return-value]

    def _sparsify_host_chunk(self, graphs, chunk, resolved, n_bucket,
                             L_bucket, B_pad, b_cap, results):
        """The oracle tail (recovery='host'): per-chunk blocking batch
        call through lgrass_sparsify_batch — kept for fidelity checks."""
        from repro.core.sparsify import lgrass_sparsify_batch

        n_fill = B_pad - len(chunk)
        batch = GraphBatch.from_graphs(
            [graphs[i] for i in chunk] + [_placeholder_graph()] * n_fill,
            n_max=n_bucket,
            L_max=L_bucket,
        )
        out = lgrass_sparsify_batch(
            batch,
            budget=list(resolved) + [None] * n_fill,
            k_cap=self.k_cap, parallel=self.parallel,
            recovery=self.recovery,
            b_cap=b_cap,
            schedule=self.schedule,
            p1_chunk=self._p1_chunk(L_bucket),
            bfs_engine=self._bfs_engine(n_bucket),
        )
        for i, r in zip(chunk, out):  # placeholder tail dropped
            results[i] = r

    def warmup(
        self,
        sizes: Iterable[Tuple[int, int]],
        batch_sizes: Sequence[int] = (1,),
        budgets: Sequence[int] = (),
    ) -> int:
        """Pre-compile bucket programs for anticipated request shapes.

        sizes: (n, L) pairs of representative requests — each is rounded
        to its bucket exactly as `sparsify` would. batch_sizes: chunk
        sizes to warm (each padded to the same batch-axis target as the
        request path — pow2, mesh-rounded when sharding). budgets:
        explicit request budgets to warm `b_cap` buckets for — without
        this, only the bucket-default b_cap program is compiled, and a
        request with a larger explicit budget costs an on-path compile
        (counted in `stats.n_on_path_compiles`). Dispatches run on
        placeholder graphs whose results are discarded; XLA's compile
        cache then serves real traffic without on-path compilation.
        Warmup goes through the SAME dispatch funnel as traffic, so the
        donated / sharded program variants are warmed when those modes
        are on. Returns the number of warmup dispatches;
        `stats.n_warmup_dispatches` / `stats.warmup_seconds` accumulate.
        """
        t0 = time.perf_counter()
        n_dispatched = 0
        for (n, L) in sizes:
            n_bucket, L_bucket = self._bucket(n, L)
            b_cap = self._b_cap(n_bucket, list(budgets))
            for B in batch_sizes:
                B_pad = self._pad_batch(int(B))
                sig = (n_bucket, L_bucket, B_pad, b_cap)
                if sig in self._warmed:
                    continue
                self._warmed.add(sig)
                if self.recovery == "host":
                    out: List[Optional[SparsifyResult]] = [None] * B_pad
                    self._sparsify_host_chunk(
                        [_placeholder_graph()] * B_pad, list(range(B_pad)),
                        [1] * B_pad, n_bucket, L_bucket, B_pad, b_cap, out)
                else:
                    d = self._dispatch(
                        [_placeholder_graph()] * B_pad, [1] * B_pad,
                        n_bucket, L_bucket, B_pad, b_cap)
                    jax.block_until_ready(d)  # compile NOW, off-path
                n_dispatched += 1
        self.stats.n_warmup_dispatches += n_dispatched
        self.stats.warmup_seconds += time.perf_counter() - t0
        return n_dispatched
