"""Flash attention forward kernel (Pallas TPU).

Tiling: grid = (B*H, S_q/block_q, S_k/block_k) with the k dimension
innermost and sequential ("arbitrary"); online-softmax statistics (m, l)
and the output accumulator live in VMEM scratch and persist across the k
iterations of one q block — the TPU-native version of flash attention's
SRAM tiling (HBM -> VMEM -> MXU instead of HBM -> shared mem -> tensor
cores). Causal and sliding-window masks come in as position vectors, so
the same kernel serves train, prefill and windowed (hymba) layers.

Block shapes default to (128, 128): MXU-aligned on both matmul dims.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _fa_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *, scale, causal,
               window: Optional[int], n_kblocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                      # (bq, d)
    k = k_ref[0]                      # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (bq, bk)

    qp = qpos_ref[...]                # (bq,)
    kp = kpos_ref[...]                # (bk,)
    mask = (kp >= 0)[None, :]
    if causal:
        mask = mask & (kp[None, :] <= qp[:, None])
    if window is not None:
        mask = mask & (kp[None, :] > qp[:, None] - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None] +
                    jax.lax.dot_general(
                        p.astype(v_ref.dtype), v_ref[0],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_cur

    @pl.when(ki == n_kblocks - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,        # (BH, Sq, d)
    k: jax.Array,        # (BH, Sk, d)
    v: jax.Array,        # (BH, Sk, d)
    qpos: jax.Array,     # (Sq,) int32, -1 = padding
    kpos: jax.Array,     # (Sk,) int32, -1 = padding
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k
    scale = d ** -0.5

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window, n_kblocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((block_q,), lambda b, qi, ki: (qi,)),
            pl.BlockSpec((block_k,), lambda b, qi, ki: (ki,)),
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qpos, kpos, q, k, v)
