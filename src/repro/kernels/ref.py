"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, qpos, kpos, *, causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q/k/v: (BH, S, d); qpos/kpos: (S,) with -1 = padding."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = (kpos >= 0)[None, None, :]
    if causal:
        mask = mask & (kpos[None, None, :] <= qpos[None, :, None])
    if window is not None:
        mask = mask & (kpos[None, None, :] > qpos[None, :, None] - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(
        q.dtype)


def bucket_rank_hist_ref(digits: jax.Array):
    """Stable rank within bucket + histogram, O(L * 256) dense."""
    nb = 256
    # dtypes pinned: under x64 a bare arange / unpinned sum would widen
    # to int64 and diverge from the int32 kernel outputs
    onehot = (digits[:, None] == jnp.arange(nb, dtype=jnp.int32)[None, :]
              ).astype(jnp.int32)
    within = jnp.cumsum(onehot, axis=0, dtype=jnp.int32) - onehot
    rank = jnp.sum(within * onehot, axis=1, dtype=jnp.int32)
    hist = jnp.sum(onehot, axis=0, dtype=jnp.int32)
    return rank, hist


def bitmap_intersect_any_ref(m1: jax.Array, m2: jax.Array) -> jax.Array:
    return jnp.any(jnp.bitwise_and(m1, m2) != 0, axis=1)


def laplacian_spmv_ref(u: jax.Array, v: jax.Array, w: jax.Array,
                       x: jax.Array) -> jax.Array:
    """y = L x via segment scatter-adds — the production formulation
    (core/spectral_probe.laplacian_spmv), so the Pallas kernel is
    validated against the exact code the estimator runs by default."""
    d = x[u] - x[v]
    c = w.astype(x.dtype)[:, None] * d
    return jnp.zeros_like(x).at[u].add(c).at[v].add(-c)


def tree_dist_pairs_ref(up: jax.Array, depth: jax.Array, a: jax.Array,
                        b: jax.Array) -> jax.Array:
    """Binary-lifting tree distance: the kernel's ground truth IS the
    production plain-gather formulation (core/lca.py), so the kernel is
    validated against the exact code the pipeline runs — one algorithm,
    two executions."""
    from repro.core.lca import LiftingTables, tree_distance

    return tree_distance(LiftingTables(up=up, depth=depth),
                         a.astype(jnp.int32), b.astype(jnp.int32))
