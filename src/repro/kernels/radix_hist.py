"""Radix histogram / rank kernel (Pallas TPU) — LGRASS §3.3 on the MXU.

The CPU radix sort keeps 256 scalar bucket counters in one cache page.
The TPU adaptation turns bucket counting into dense linear algebra:

    one_hot  = (digits[:, None] == iota(256))          (C, 256) on the VPU
    hist    += one_hot^T @ 1                            column sum
    rank     = one_hot @ carry + row-prefix(one_hot)    MXU matmul + cumsum

The grid walks chunks sequentially ("arbitrary"); the running per-bucket
carry lives in VMEM scratch, so one kernel pass yields every element's
stable rank *within its bucket* plus the global histogram — exactly the
two quantities a counting-sort pass needs. ops.py composes 4 passes of
this into the full uint32 radix argsort.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NB = 256


def _hist_kernel(d_ref, rank_ref, hist_ref, carry_ref, *, n_chunks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    d = d_ref[...]                                    # (C,) int32
    c = d.shape[0]
    buckets = jax.lax.broadcasted_iota(jnp.int32, (c, NB), 1)
    onehot = (d[:, None] == buckets).astype(jnp.int32)      # (C, NB)
    # sum/cumsum dtypes pinned: x64 promotion would widen to int64 and
    # the stores into the int32 refs fail
    within = jnp.cumsum(onehot, axis=0, dtype=jnp.int32) - onehot
    carry = carry_ref[...]                                  # (NB,)
    # rank = carry[digit] + row prefix, both as dense contractions
    rank = (jnp.sum(onehot * carry[None, :], axis=1, dtype=jnp.int32) +
            jnp.sum(within * onehot, axis=1, dtype=jnp.int32))
    rank_ref[...] = rank
    carry_ref[...] = carry + jnp.sum(onehot, axis=0, dtype=jnp.int32)

    @pl.when(i == n_chunks - 1)
    def _flush():
        hist_ref[...] = carry_ref[...]


def bucket_rank_hist(digits: jax.Array, *, chunk: int = 1024,
                     interpret: bool = False):
    """digits: (L,) int32 in [0, 256). Returns (rank_in_bucket, hist)."""
    m = digits.shape[0]
    assert m % chunk == 0, "pad digits to a chunk multiple"
    n_chunks = m // chunk
    kernel = functools.partial(_hist_kernel, n_chunks=n_chunks)
    rank, hist = pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((chunk,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((NB,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((NB,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((NB,), jnp.int32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(digits)
    return rank, hist
