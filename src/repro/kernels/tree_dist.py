"""Binary-lifting tree-distance kernel (Pallas TPU) — the hot gather of
the recovery coverage test.

The Algorithm-6 replay asks, per scanned edge, for tree hop distances
from its endpoints to every buffered accepted endpoint. Each distance is
an LCA climb: O(log n) dependent gathers from the (LOG, n) lifting
table. On TPU a data-dependent gather is the wrong native shape; the
dense mapping (same idiom as radix_hist.py) is a one-hot contraction —
`table[idx]` becomes `onehot(idx) @ table` on the VPU/MXU. The whole
lifting table stays resident in VMEM across the grid, so one kernel call
answers a block of query pairs with zero HBM pointer chasing.

VMEM bound: the kernel materialises (block, n) one-hots, so it targets
the serving regime (n up to a few thousand per graph); ops.py picks the
block size and pads queries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _gather(row: jax.Array, idx: jax.Array, n: int) -> jax.Array:
    """row: (n,) int32; idx: (C,) int32 -> row[idx] via one-hot contraction.

    The sum dtype is pinned: under x64 numpy-style promotion would widen
    the contraction to int64 and the store into the int32 out ref fails.
    """
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], n), 1)
    onehot = (idx[:, None] == cols).astype(jnp.int32)
    return jnp.sum(onehot * row[None, :], axis=1, dtype=jnp.int32)


def _tree_dist_kernel(up_ref, depth_ref, a_ref, b_ref, out_ref, *,
                      log: int, n: int):
    up = up_ref[...]        # (LOG, n)
    depth = depth_ref[...]  # (n,)
    a = a_ref[...]          # (block,)
    b = b_ref[...]
    da = _gather(depth, a, n)
    db = _gather(depth, b, n)
    # lift the deeper endpoint to the shallower one's level
    ka = jnp.maximum(da - db, 0)
    kb = jnp.maximum(db - da, 0)
    ca, cb = a, b
    for i in range(log):
        ca = jnp.where(((ka >> i) & 1) == 1, _gather(up[i], ca, n), ca)
        cb = jnp.where(((kb >> i) & 1) == 1, _gather(up[i], cb, n), cb)
    # descend in lockstep to just below the LCA
    for i in range(log):
        k = log - 1 - i
        ua = _gather(up[k], ca, n)
        ub = _gather(up[k], cb, n)
        jump = (ca != cb) & (ua != ub)
        ca = jnp.where(jump, ua, ca)
        cb = jnp.where(jump, ub, cb)
    w = jnp.where(ca == cb, ca, _gather(up[0], ca, n))
    out_ref[...] = da + db - 2 * _gather(depth, w, n)


def tree_dist_pairs(up: jax.Array, depth: jax.Array, a: jax.Array,
                    b: jax.Array, *, block: int = 128,
                    interpret: bool = False) -> jax.Array:
    """up: (LOG, n) int32 lifting table; depth: (n,) int32; a, b: (M,)
    int32 query pairs. Returns (M,) int32 tree hop distances."""
    log, n = up.shape
    m = a.shape[0]
    assert m % block == 0, "pad queries to a block multiple"
    kernel = functools.partial(_tree_dist_kernel, log=log, n=n)
    return pl.pallas_call(
        kernel,
        grid=(m // block,),
        in_specs=[
            pl.BlockSpec((log, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(up, depth, a, b)
