"""jit'd public wrappers for the Pallas kernels.

`interpret=None` auto-selects: compiled Mosaic on TPU backends, Pallas
interpret mode elsewhere (CPU CI) — same kernel body either way.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.bitmap_intersect import bitmap_intersect_any as _bitmap
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.radix_hist import bucket_rank_hist as _brh
from repro.kernels.spmv import laplacian_spmv as _spmv
from repro.kernels.tree_dist import tree_dist_pairs as _tdp


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=None,
                    qpos=None, kpos=None, block_q=128, block_k=128,
                    interpret: Optional[bool] = None):
    """q: (B, Sq, H, d); k/v: (B, Sk, Kv, d) (GQA kv repeated as needed).

    Returns (B, Sq, H, d).
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    if kvh != h:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kb = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], d)
    vb = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], d)
    if qpos is None:
        qpos = jnp.arange(sq, dtype=jnp.int32)
    if kpos is None:
        kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    out = flash_attention_bhsd(
        qb, kb, vb, qpos.astype(jnp.int32), kpos.astype(jnp.int32),
        causal=causal, window=window, block_q=block_q, block_k=block_k,
        interpret=_auto_interpret(interpret))
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def bucket_rank_hist(digits, *, chunk=1024,
                     interpret: Optional[bool] = None):
    m = digits.shape[0]
    pad = (-m) % chunk
    if pad:
        digits = jnp.concatenate(
            [digits, jnp.full((pad,), 255, digits.dtype)])
    rank, hist = _brh(digits.astype(jnp.int32), chunk=chunk,
                      interpret=_auto_interpret(interpret))
    if pad:
        hist = hist.at[255].add(-pad)
        rank = rank[:m]
    return rank, hist


def radix_argsort_u32(keys, *, chunk=1024,
                      interpret: Optional[bool] = None):
    """Stable ascending argsort via 4 byte passes of the Pallas kernel."""
    m = keys.shape[0]
    perm = jnp.arange(m, dtype=jnp.int32)
    for shift in (0, 8, 16, 24):
        cur = keys[perm]
        digits = ((cur >> shift) & jnp.uint32(0xFF)).astype(jnp.int32)
        rank, hist = bucket_rank_hist(digits, chunk=chunk,
                                      interpret=interpret)
        offsets = jnp.cumsum(hist) - hist
        pos = offsets[digits] + rank
        perm = jnp.zeros((m,), jnp.int32).at[pos].set(perm)
    return perm


def tree_dist_pairs(up, depth, a, b, *, block=128,
                    interpret: Optional[bool] = None):
    """Tree hop distances for (M,) query pairs via the lifting-table
    kernel. Queries are padded to a block multiple (pad lanes query node
    0 against itself and are sliced away)."""
    m = a.shape[0]
    block = min(block, max(m, 1))
    pad = (-m) % block
    if pad:
        z = jnp.zeros((pad,), jnp.int32)
        a = jnp.concatenate([a.astype(jnp.int32), z])
        b = jnp.concatenate([b.astype(jnp.int32), z])
    out = _tdp(up, depth, a.astype(jnp.int32), b.astype(jnp.int32),
               block=block, interpret=_auto_interpret(interpret))
    return out[:m] if pad else out


def laplacian_spmv_edges(u, v, w, x, *, block=512,
                         interpret: Optional[bool] = None):
    """y = L x via the gather-scatter spmv kernel. u/v/w: (M,) edge
    list (w == 0.0 marks padding / masked slots); x: (n, P) float32
    probe block. Edges are padded to a block multiple with zero-weight
    self loops, which contribute exactly nothing."""
    m = u.shape[0]
    if m == 0:
        return jnp.zeros_like(x)
    block = min(block, max(m, 1))
    pad = (-m) % block
    if pad:
        z = jnp.zeros((pad,), jnp.int32)
        u = jnp.concatenate([u.astype(jnp.int32), z])
        v = jnp.concatenate([v.astype(jnp.int32), z])
        w = jnp.concatenate([w.astype(jnp.float32),
                             jnp.zeros((pad,), jnp.float32)])
    return _spmv(u.astype(jnp.int32), v.astype(jnp.int32),
                 w.astype(jnp.float32), x.astype(jnp.float32),
                 block=block, interpret=_auto_interpret(interpret))


def bitmap_intersect_any(m1, m2, *, block=1024,
                         interpret: Optional[bool] = None):
    l, w = m1.shape
    pad = (-l) % block
    if pad:
        z = jnp.zeros((pad, w), m1.dtype)
        m1 = jnp.concatenate([m1, z])
        m2 = jnp.concatenate([m2, z])
    out = _bitmap(m1, m2, block=block, interpret=_auto_interpret(interpret))
    return out[:l]
