"""Gather-scatter Laplacian spmv kernel (Pallas TPU) — the probe
estimator's inner loop as dense MXU contractions.

y = L x with L = Σ_e w_e (e_u − e_v)(e_u − e_v)ᵀ. Per grid step a block
of C edges builds the signed incidence slab S = onehot(u) − onehot(v)
((C, n), VPU compares), and two MXU matmuls do the gather AND the
scatter: d = S @ x pulls both endpoints' probe rows in one contraction,
and acc += Sᵀ @ (w ⊙ d) pushes the weighted differences back — no
data-dependent addressing anywhere (the one-hot idiom of tree_dist.py /
radix_hist.py). The (n, P) accumulator lives in VMEM scratch across the
sequential grid and flushes once on the last block. Zero-weight rows
(edge padding, masked batch slots) contribute exactly nothing, so the
caller only has to zero w.

VMEM bound: x, the accumulator, and the (C, n) slab must fit — the
kernel targets the serving regime (n up to a few thousand).
core/spectral_probe.py keeps the pure-XLA segment-sum spmv as the
default path; this kernel is the TPU-native swap-in behind
`use_spmv_kernel=True` (ops.py pads edge blocks and picks interpret
mode per backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _spmv_kernel(u_ref, v_ref, w_ref, x_ref, out_ref, acc_ref, *,
                 n_blocks: int, n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    u = u_ref[...]                                    # (C,) int32
    v = v_ref[...]
    w = w_ref[...]                                    # (C,) float32
    x = x_ref[...]                                    # (n, P) float32
    c = u.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (c, n), 1)
    # signed incidence slab: +1 at u, −1 at v, 0 elsewhere (a self-loop
    # padding row u == v cancels to all-zero on its own)
    s = ((u[:, None] == cols).astype(jnp.float32)
         - (v[:, None] == cols).astype(jnp.float32))
    d = jnp.dot(s, x, preferred_element_type=jnp.float32)       # gather
    acc_ref[...] += jnp.dot(s.T, w[:, None] * d,                # scatter
                            preferred_element_type=jnp.float32)

    @pl.when(i == n_blocks - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


def laplacian_spmv(u: jax.Array, v: jax.Array, w: jax.Array,
                   x: jax.Array, *, block: int = 512,
                   interpret: bool = False) -> jax.Array:
    """u, v: (M,) int32; w: (M,) float32 (0.0 on padding slots);
    x: (n, P) float32 probe block. Returns (n, P) float32 y = L x."""
    m = u.shape[0]
    n, p = x.shape
    assert m % block == 0, "pad edges to a block multiple"
    n_blocks = m // block
    kernel = functools.partial(_spmv_kernel, n_blocks=n_blocks, n=n)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((n, p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(u, v, w, x)
