"""Bitmap set-intersection kernel (Pallas TPU) — LGRASS Alg. 5's
"M_{lca,u} ∩ M_{lca,v} is not empty" test.

The paper accelerates mark-set intersection with bitmaps + SIMD (FESIA
style). The TPU analogue is a VPU kernel over (block, W) uint32 lanes:
AND + any-reduce per edge row, with the edge dimension tiled through VMEM.
One memory pass, no MXU involvement — this is the paper's "classic
acceleration technique for set operations" mapped onto the vector unit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _bitmap_kernel(m1_ref, m2_ref, out_ref):
    inter = jnp.bitwise_and(m1_ref[...], m2_ref[...])   # (block, W)
    out_ref[...] = jnp.any(inter != 0, axis=1)


def bitmap_intersect_any(m1: jax.Array, m2: jax.Array, *,
                         block: int = 1024,
                         interpret: bool = False) -> jax.Array:
    """m1, m2: (L, W) uint32 bitmaps. Returns (L,) bool non-empty flags."""
    l, w = m1.shape
    assert m1.shape == m2.shape
    assert l % block == 0, "pad rows to a block multiple"
    return pl.pallas_call(
        _bitmap_kernel,
        grid=(l // block,),
        in_specs=[
            pl.BlockSpec((block, w), lambda i: (i, 0)),
            pl.BlockSpec((block, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), jnp.bool_),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(m1, m2)
