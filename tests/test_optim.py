"""Optimizer + gradient compression unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import compression as comp
from repro.optim.optimizer import (OptConfig, adamw_update,
                                   clip_by_global_norm, init_opt_state,
                                   lr_schedule)


def _np_adamw(p, g, m, v, step, cfg):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** step)
    vh = v / (1 - cfg.b2 ** step)
    lr = cfg.peak_lr * step / cfg.warmup_steps if step < cfg.warmup_steps \
        else None
    return m, v, mh, vh


def test_adamw_matches_numpy_reference():
    cfg = OptConfig(peak_lr=1e-2, warmup_steps=1000, total_steps=2000,
                    weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    grads = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]], jnp.float32)}
    state = init_opt_state(params)
    newp, newstate, m = adamw_update(params, grads, state, cfg)
    g = np.asarray(grads["w"])
    mm, vv, mh, vh = _np_adamw(np.asarray(params["w"]), g,
                               np.zeros((2, 2)), np.zeros((2, 2)), 1, cfg)
    lr = 1e-2 * 1 / 1000
    want = np.asarray(params["w"]) - lr * mh / (np.sqrt(vh) + cfg.eps)
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(newstate["mu"]["w"]), mm,
                               rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 6.0) < 1e-5
    np.testing.assert_allclose(np.asarray(clipped["a"]),
                               np.ones(4) * 0.5, rtol=1e-5)


def test_lr_schedule_shape():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == 0.5
    assert abs(lrs[2] - 1.0) < 0.05
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 0.1) < 1e-3  # decays to 10%


def test_topk_error_feedback_unbiased_over_time():
    """With error feedback, sum of compressed grads ~= sum of true grads."""
    rng = np.random.default_rng(0)
    err = jnp.zeros((100,), jnp.float32)
    total_sent, total_true = np.zeros(100), np.zeros(100)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(100), jnp.float32)
        sent, err = comp.topk_compress(g, 0.1, err)
        total_sent += np.asarray(sent)
        total_true += np.asarray(g)
    resid = np.abs(total_sent - total_true).max()
    assert resid < 10.0  # bounded by max |err| (not growing with steps)


def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    q, s = comp.int8_quantize(g)
    deq = comp.int8_dequantize(q, s, g.shape)
    err = np.abs(np.asarray(g) - deq).max()
    assert err <= float(np.abs(np.asarray(g)).max()) / 127.0 + 1e-6


def test_int8_ef_state():
    g = jnp.asarray([[1.0, -0.003, 2.0]], jnp.float32)
    sent, err = comp.int8_roundtrip(g, jnp.zeros_like(g))
    np.testing.assert_allclose(np.asarray(sent) + np.asarray(err),
                               np.asarray(g), atol=1e-6)
