"""`hlo_analysis.analyze_jitted`: one lowering path for any jitted
callable, plus the transfer/alias report the jaxpr auditor consumes.
Donation must be verified on the COMPILED artifact — `donate_argnums`
the compiler silently drops never shows up in a jaxpr."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, analyze_jitted, parse_output_alias

F = jax.ShapeDtypeStruct((256,), jnp.float32)


def test_analyze_jitted_plain_callable():
    report = analyze_jitted(lambda x, y: x @ y,
                            jax.ShapeDtypeStruct((32, 32), jnp.float32),
                            jax.ShapeDtypeStruct((32, 32), jnp.float32))
    assert report["flops"] >= 2 * 32 * 32 * 32
    assert report["transfer_count"] == 0
    assert report["output_alias"] == []


def test_analyze_jitted_prejitted_with_statics():
    @jax.jit
    def f(x, scale=2.0):
        return x * scale

    report = analyze_jitted(f, F, static_kwargs=dict(scale=3.0))
    assert report["transfer_count"] == 0
    assert report["n_computations"] >= 1


def test_donated_program_reports_alias():
    g = jax.jit(lambda x, y: x * 2 + y, donate_argnums=(0,))
    report = analyze_jitted(g, F, F)
    assert len(report["output_alias"]) == 1
    alias = report["output_alias"][0]
    assert alias["parameter"] == 0
    assert alias["kind"] in ("may-alias", "must-alias")
    # wrapping the same fn in a fresh jit drops the donation
    plain = analyze_jitted(lambda x, y: x * 2 + y, F, F)
    assert plain["output_alias"] == []


def test_service_donated_dispatch_aliases_buffers():
    from repro.serve.sparsify_service import SparsifyService

    svc = SparsifyService(donate=True)
    spec = svc.program_specs([(64, 128)], batch_sizes=(2,))[0]
    assert spec.name.startswith("lgrass_device_batched[donated]")
    report = analyze_jitted(spec.fn, *spec.args,
                            static_kwargs=spec.static_kwargs)
    assert report["transfer_count"] == 0
    assert len(report["output_alias"]) >= 1


def test_parse_output_alias_tuple_indices():
    header = ("HloModule jit_f, input_output_alias="
              "{ {0}: (3, {}, must-alias), {1, 2}: (4, {}, may-alias) }, "
              "entry_computation_layout={()->f32[8]{0}}")
    aliases = parse_output_alias(header)
    assert aliases == [
        dict(output_index=[0], parameter=3, kind="must-alias"),
        dict(output_index=[1, 2], parameter=4, kind="may-alias"),
    ]
    assert parse_output_alias("HloModule jit_f") == []


def test_analyze_text_keys_are_stable():
    g = jax.jit(lambda x: jnp.sort(x))
    text = g.lower(F).compile().as_text()
    report = analyze(text)
    for key in ("flops", "mem_bytes", "collective_bytes",
                "transfer_count", "output_alias", "entry"):
        assert key in report
