"""Multi-device tests (subprocess with XLA_FLAGS=8 fake devices):
sharded LGRASS phase-1 equivalence, elastic re-meshing, compressed psum,
and a reduced-mesh dry-run through the real launch machinery."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_phase1_equals_local():
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import random_connected_graph
        from repro.core.distributed import lgrass_phase1_distributed
        from repro.core.sparsify import phase1_device
        for seed in (0, 3):
            g = random_connected_graph(60, 140, seed=seed)
            mesh = jax.make_mesh((8,), ('data',))
            acc, dirty, d = lgrass_phase1_distributed(g, mesh, ('data',))
            u = jnp.asarray(g.u, jnp.int32); v = jnp.asarray(g.v, jnp.int32)
            w = jnp.asarray(g.w, jnp.float32)
            ds = jax.device_get(phase1_device(u, v, w, g.n, 32, True))
            ref = np.zeros(g.m, bool); ref[ds['perm']] = ds['accept_sorted']
            assert np.array_equal(acc, ref), seed
        print('OK')
    """)
    assert "OK" in out


def test_distributed_sparsify_equals_oracle():
    out = _run("""
        import jax, numpy as np
        from repro.core import random_connected_graph, baseline_sparsify
        from repro.core.distributed import lgrass_phase1_distributed
        from repro.core import _host as H
        from repro.core.recovery import recover
        g = random_connected_graph(50, 120, seed=5)
        b = baseline_sparsify(g, budget=10)
        mesh = jax.make_mesh((8,), ('data',))
        acc, dirty, d = lgrass_phase1_distributed(g, mesh, ('data',))
        tree = d['tree_mask'].astype(bool)
        crossing = d['crossing'].astype(bool)
        perm = d['perm'].astype(np.int64)
        group = np.full(g.m, -1, np.int64)
        group[perm] = d['gidx'].astype(np.int64)
        group[~crossing] = -1
        keys = np.where(~tree, d['crit'], np.float32(-np.inf))
        order = H.desc_stable_order_np(keys)[: int((~tree).sum())]
        final = recover(g.n, g.u.astype(np.int64), g.v.astype(np.int64),
                        tree, d['parent_t'], d['depth_t'], d['up'],
                        d['beta'], crossing, order, acc, group, dirty, 10)
        assert np.array_equal(tree | final, b.edge_mask)
        print('OK')
    """)
    assert "OK" in out


def test_elastic_remesh_and_compressed_psum():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.ft.elastic import remesh_state
        from repro.optim.compression import compressed_psum

        # remesh 8 -> 4+idle devices (different topology)
        mesh8 = jax.make_mesh((8,), ('data',))
        mesh42 = jax.make_mesh((4, 2), ('data', 'model'))
        x = jax.device_put(np.arange(32, dtype=np.float32),
                           NamedSharding(mesh8, P('data')))
        state = {'w': x}
        spec = {'w': P('data')}
        out = remesh_state(state, spec, mesh42)
        assert np.array_equal(np.asarray(out['w']), np.arange(32))
        assert out['w'].sharding.mesh.shape['data'] == 4

        # compressed psum ~= exact psum
        mesh = jax.make_mesh((8,), ('d',))
        xs = np.random.default_rng(0).standard_normal((8, 64)).astype(
            np.float32)
        from repro.compat import shard_map
        f = jax.jit(shard_map(
            lambda a: compressed_psum(a[0], 'd')[None],
            mesh=mesh, in_specs=P('d'), out_specs=P('d')))
        got = np.asarray(f(xs))[0]
        want = xs.sum(0)
        err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert err < 0.05, err
        print('OK')
    """)
    assert "OK" in out


def test_reduced_mesh_dryrun_machinery():
    """Run the real dry-run flow (specs -> lower -> compile -> analyze) on
    an 8-device (2,2,2) pod/data/model mesh for two architectures."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro import compat
        import repro.launch.mesh as M
        # shrink the production mesh for the 8-device CI environment
        M.make_production_mesh = lambda multi_pod=False: compat.make_mesh(
            (2, 2, 2) if multi_pod else (4, 2),
            ('pod', 'data', 'model') if multi_pod else ('data', 'model'))
        from repro.launch import dryrun
        import repro.launch.dryrun as D
        rec1 = D.run_cell('mamba2-370m', 'train_4k', True, '/tmp/ci_dry',
                          force=True, micro_batches=2)
        assert rec1['hlo_flops_per_device'] > 0
        assert rec1['collective_bytes_per_device'] > 0
        rec2 = D.run_cell('granite-moe-3b-a800m', 'decode_32k', False,
                          '/tmp/ci_dry', force=True)
        assert rec2['memory']['temp_bytes'] > 0
        rec3 = D.run_lgrass_cell('case1_4k', True, '/tmp/ci_dry',
                                 force=True)
        assert rec3['hlo_bytes_per_device'] > 0
        print('OK')
    """, timeout=900)
    assert "OK" in out


def test_elastic_restart_on_smaller_mesh(tmp_path):
    """End-to-end elasticity: train on an 8-device mesh, checkpoint,
    restore + reshard onto a 4-device mesh, continue training — loss
    trajectory must continue from the checkpointed state."""
    out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import ARCHS
        from repro.models.model import LM
        from repro.data.pipeline import DataConfig, TokenPipeline
        from repro.optim.optimizer import OptConfig
        from repro.train.train_step import make_train_state, make_train_step
        from repro.ckpt.checkpoint import Checkpointer
        from repro.ft.elastic import remesh_state, resolve_spec_for_mesh

        cfg = ARCHS['phi3-mini-3.8b'].reduced()
        model = LM(cfg)
        opt = OptConfig(peak_lr=5e-3, warmup_steps=2, total_steps=20)
        data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=16, global_batch=8, seed=3))
        step = jax.jit(make_train_step(model, opt))
        ck = Checkpointer({str(tmp_path)!r}, async_save=False)

        # phase 1: 8-device data-parallel mesh
        mesh8 = jax.make_mesh((8,), ('data',))
        state = make_train_state(model, jax.random.PRNGKey(0))
        state = jax.device_put(state, NamedSharding(mesh8, P()))
        losses = []
        for i in range(6):
            batch = jax.device_put(data.batch(i),
                                   NamedSharding(mesh8, P('data')))
            state, m = step(state, batch)
            losses.append(float(m['loss']))
        ck.save(6, state)

        # phase 2: 'failure' -> resume on a 4-device mesh
        mesh4 = jax.make_mesh((4, 2), ('data', 'model'))
        template = jax.tree.map(np.asarray, jax.device_get(state))
        restored = ck.restore(6, template)
        spec_tree = jax.tree.map(lambda _: P(), restored)
        state2 = remesh_state(restored, spec_tree, mesh4)
        for i in range(6, 12):
            batch = jax.device_put(data.batch(i),
                                   NamedSharding(mesh4, P('data')))
            state2, m = step(state2, batch)
            losses.append(float(m['loss']))
        assert int(state2['opt']['step']) == 12
        assert all(np.isfinite(losses))
        print('OK', round(losses[0], 3), round(losses[-1], 3))
    """)
    assert "OK" in out
