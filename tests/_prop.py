"""Dependency-free property sweeps (stdlib + numpy stand-in for hypothesis).

The seed suite used `hypothesis.given`; that package is not part of the
pinned environment, so properties are exercised as seeded pseudo-random
parameter sweeps instead: each draw spec is a callable `rng -> value`,
and `cases(...)` materialises ~20 deterministic tuples for
`pytest.mark.parametrize`. Same coverage intent (including the `ties`
weight mode and heavy duplicate keys), fully reproducible, no shrinking.
"""
from __future__ import annotations

import numpy as np


def integers(lo: int, hi: int):
    """Draw an int uniformly from [lo, hi] (inclusive, like hypothesis)."""
    return lambda rng: int(rng.integers(lo, hi + 1))


def sampled_from(choices):
    seq = list(choices)
    return lambda rng: seq[int(rng.integers(len(seq)))]


def float32_lists(min_value: float, max_value: float,
                  min_size: int, max_size: int):
    """Non-negative float32 lists; half the draws come from a small value
    pool so equal-key (stability) paths are hit hard."""

    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        if rng.random() < 0.5:
            pool = rng.uniform(min_value, max_value, size=4)
            xs = rng.choice(pool, size=size)
        else:
            xs = rng.uniform(min_value, max_value, size=size)
        return np.asarray(xs, np.float32).tolist()

    return draw


def cases(*draws, n_cases: int = 20, seed: int = 0):
    """Materialise `n_cases` tuples (or scalars, for a single draw) for
    pytest.mark.parametrize. Deterministic in (draw specs, seed)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_cases):
        vals = tuple(d(rng) for d in draws)
        out.append(vals if len(vals) > 1 else vals[0])
    return out
