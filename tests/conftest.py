import os
import sys

import pytest

# Tests see exactly one device unless a test spawns its own subprocess
# with XLA_FLAGS (the dry-run needs 512 placeholder devices; smoke tests
# must NOT).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False,
        help="run tests marked slow (10^6-node scale tier)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: excluded from tier-1; enable with --run-slow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="needs --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
