import os
import sys

# Tests see exactly one device unless a test spawns its own subprocess
# with XLA_FLAGS (the dry-run needs 512 placeholder devices; smoke tests
# must NOT).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
