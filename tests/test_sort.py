"""Radix sort (LGRASS §3.3): linearity-preserving IEEE-754 key trick,
stability, and equivalence with numpy sorts."""
import jax.numpy as jnp
import numpy as np
import pytest

from _prop import cases, float32_lists
from repro.core.sort import (
    bucket_ranks,
    float32_sort_key,
    radix_argsort_u32,
    radix_argsort_u64pair,
    sort_f32_desc_stable,
)


def test_float_key_monotone():
    xs = np.array([0.0, 1e-38, 0.5, 1.0, 3.14, 1e30, -1.0, -0.5, -1e30],
                  np.float32)
    keys = np.asarray(float32_sort_key(jnp.asarray(xs)))
    order_f = np.argsort(xs, kind="stable")
    order_k = np.argsort(keys, kind="stable")
    assert np.array_equal(xs[order_f], xs[order_k])


@pytest.mark.parametrize("engine", ["radix", "xla", None])
@pytest.mark.parametrize("n", [1, 7, 256, 1024, 5000])
def test_radix_u32_matches_numpy(n, engine):
    rng = np.random.default_rng(n)
    keys = rng.integers(0, 2 ** 32, n, dtype=np.uint32)
    perm = np.asarray(radix_argsort_u32(jnp.asarray(keys), engine=engine))
    assert np.array_equal(keys[perm], np.sort(keys))


@pytest.mark.parametrize("engine", ["radix", "xla", None])
def test_radix_u32_stable(engine):
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 4, 2000, dtype=np.uint32)  # heavy ties
    perm = np.asarray(radix_argsort_u32(jnp.asarray(keys), engine=engine))
    ref = np.argsort(keys, kind="stable")
    assert np.array_equal(perm, ref)


@pytest.mark.parametrize("engine", ["radix", "xla", None])
def test_radix_u64pair(engine):
    rng = np.random.default_rng(1)
    hi = rng.integers(0, 3, 1500, dtype=np.uint32)
    lo = rng.integers(0, 2 ** 32, 1500, dtype=np.uint32)
    perm = np.asarray(radix_argsort_u64pair(jnp.asarray(hi), jnp.asarray(lo),
                                            engine=engine))
    key = hi.astype(np.uint64) << np.uint64(32) | lo.astype(np.uint64)
    assert np.array_equal(perm, np.argsort(key, kind="stable"))


def test_engines_identical_permutation():
    """The engine choice must be unobservable: same stable permutation
    for heavy-tie and distinct keys alike."""
    rng = np.random.default_rng(7)
    for n in (1, 300, 2048):
        keys = jnp.asarray(rng.integers(0, 5, n, dtype=np.uint32))
        assert np.array_equal(
            np.asarray(radix_argsort_u32(keys, engine="radix")),
            np.asarray(radix_argsort_u32(keys, engine="xla")))
        hi = jnp.asarray(rng.integers(0, 3, n, dtype=np.uint32))
        lo = jnp.asarray(rng.integers(0, 7, n, dtype=np.uint32))
        assert np.array_equal(
            np.asarray(radix_argsort_u64pair(hi, lo, engine="radix")),
            np.asarray(radix_argsort_u64pair(hi, lo, engine="xla")))


def test_desc_stable():
    keys = np.array([1.0, 3.0, 3.0, 0.5, 3.0, 2.0], np.float32)
    perm = np.asarray(sort_f32_desc_stable(jnp.asarray(keys)))
    assert perm.tolist() == [1, 2, 4, 5, 0, 3]


@pytest.mark.parametrize(
    "xs",
    cases(float32_lists(0, 1e6, min_size=1, max_size=300),
          n_cases=25, seed=11),
)
def test_desc_stable_property(xs):
    keys = np.array(xs, np.float32)
    perm = np.asarray(sort_f32_desc_stable(jnp.asarray(keys)))
    srt = keys[perm]
    assert np.all(np.diff(srt) <= 0)  # descending
    # stability: equal keys keep index order
    for i in range(len(perm) - 1):
        if srt[i] == srt[i + 1]:
            assert perm[i] < perm[i + 1]


@pytest.mark.parametrize("nb", [4, 16, 256])
def test_bucket_ranks(nb):
    rng = np.random.default_rng(nb)
    keys = rng.integers(0, nb, 4000)
    ranks = np.asarray(bucket_ranks(jnp.asarray(keys, jnp.int32), nb))
    seen = {}
    for i, k in enumerate(keys):
        assert ranks[i] == seen.get(k, 0)
        seen[k] = seen.get(k, 0) + 1
