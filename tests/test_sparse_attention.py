"""Beyond-paper integration tests: LGRASS attention-mask planner."""
import numpy as np
import jax.numpy as jnp

from repro.sparse.attention_graph import (block_sparse_attention,
                                          build_block_graph,
                                          plan_block_mask)


def _feats(nb=16, d=32, seed=0):
    return np.random.default_rng(seed).standard_normal((nb, d)).astype(
        np.float32)


def test_block_graph_valid():
    g = build_block_graph(_feats(), window=2)
    g.validate()
    assert g.n == 16


def test_plan_mask_causal_and_connected():
    plan = plan_block_mask(_feats(24), keep_frac=0.2)
    nb = plan.n_blocks
    assert plan.mask.shape == (nb, nb)
    # strictly causal below diag + full diag
    assert np.all(np.diag(plan.mask))
    assert not np.any(np.triu(plan.mask, 1))
    # undirected connectivity via spanning tree
    adj = plan.mask | plan.mask.T
    seen = {0}
    frontier = [0]
    while frontier:
        nxt = []
        for x in frontier:
            for y in np.where(adj[x])[0]:
                if int(y) not in seen:
                    seen.add(int(y))
                    nxt.append(int(y))
        frontier = nxt
    assert len(seen) == nb


def test_block_sparse_attention_dense_mask_equals_dense():
    rng = np.random.default_rng(1)
    B, S, H, D, blk = 1, 128, 2, 16, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    nb = S // blk
    full = block_sparse_attention(q, k, v, jnp.ones((nb, nb), bool), blk)
    # reference dense causal attention
    scale = D ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    causal = jnp.tril(jnp.ones((S, S), bool))
    p = jnp.asarray(np.asarray(
        jnp.einsum("bhqk,bkhd->bqhd",
                   jnp.asarray(np.asarray(
                       jnp.exp(jnp.where(causal, s, -1e9)) /
                       jnp.sum(jnp.exp(jnp.where(causal, s, -1e9)), -1,
                               keepdims=True))), v)))
    np.testing.assert_allclose(np.asarray(full), np.asarray(p),
                               atol=1e-4, rtol=1e-4)
