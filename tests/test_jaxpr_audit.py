"""Jaxpr auditor (`analysis.jaxpr_audit`): the standard program set
traces clean, the loop/dtype/dispatch checks catch seeded violations,
`audit_service` covers a live service's signatures, and the
`python -m repro.analysis` CLI honours its exit-code contract."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.jaxpr_audit import (
    EXPECTED_WHILE,
    audit_program,
    audit_service,
    check_derived_constants,
    collect_eqns,
    standard_program_audits,
)

F32 = jax.ShapeDtypeStruct((16,), jnp.float32)


# --------------------------------------------------- standard program set

def test_standard_programs_all_clean():
    reports = standard_program_audits()
    assert len(reports) >= 10
    bad = {r.name: r.findings for r in reports if not r.ok}
    assert bad == {}
    # every serving-path program is a single dispatch
    assert all(r.dispatch_count == 1 for r in reports)


def test_loop_budgets_are_engine_dependent():
    byname = {r.name: r for r in standard_program_audits()}
    assert byname["lgrass_device[doubling]"].n_while == \
        EXPECTED_WHILE[("lgrass", "doubling")]
    assert byname["lgrass_device[levels]"].n_while == \
        EXPECTED_WHILE[("lgrass", "levels")]
    assert byname["probe_edge_resistance"].n_while == 0


def test_derived_constants_agree():
    assert check_derived_constants() == []


# ------------------------------------------------------- seeded violations

def test_extra_while_loop_flags():
    def extra_loop(x):
        y = jax.lax.while_loop(lambda c: c[1] < 3,
                               lambda c: (c[0] * 2, c[1] + 1),
                               (x, jnp.int32(0)))[0]
        return y

    rep = audit_program("seeded", extra_loop, (F32,), expected_while=0)
    assert not rep.ok and "while-loop count 1" in rep.findings[0]


def test_undocumented_scan_length_flags():
    def long_scan(x):
        return jax.lax.scan(lambda c, _: (c + 1.0, None), x,
                            None, length=99)[0]

    rep = audit_program("seeded", long_scan, (F32,),
                        allowed_scan_lengths={7, 16, 32})
    assert not rep.ok and "99" in rep.findings[0]


def test_callback_primitive_flags_dispatch():
    def chatty(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    rep = audit_program("seeded", chatty, (F32,))
    assert rep.dispatch_count > 1
    assert any("callback" in f for f in rep.findings)


def test_weak_typed_output_flags():
    def weak(x):
        return 1.0  # bare Python literal escapes as a weak output

    rep = audit_program("seeded", weak, (F32,))
    assert any("weakly typed" in f for f in rep.findings)


def test_collect_eqns_recurses_into_loops():
    def nested(x):
        def body(c, _):
            return jax.lax.while_loop(lambda v: jnp.any(v < 0),
                                      lambda v: v + 1, c), None
        return jax.lax.scan(body, x, None, length=3)[0]

    names = [e.primitive.name for e in
             collect_eqns(jax.make_jaxpr(nested)(jnp.zeros(4)))]
    assert "scan" in names and "while" in names
    assert "add" in names  # from inside the while body, two levels down


# ------------------------------------------------------------ service audit

def test_audit_service_signatures():
    from repro.serve.sparsify_service import SparsifyService

    svc = SparsifyService()
    reports = audit_service(svc, sizes=[(64, 128)], batch_sizes=(1, 2))
    assert len(reports) == 2
    assert all(r.ok for r in reports), [r.findings for r in reports]
    assert all(r.dispatch_count == 1 for r in reports)


# ---------------------------------------------------------------- the CLI

def test_cli_seeded_bugs_exit_nonzero(capsys):
    from repro.analysis.__main__ import main

    assert main(["--seed-bug", "inf-depth"]) != 0
    assert "CAUGHT" in capsys.readouterr().out
    assert main(["--seed-bug", "pack-overflow"]) != 0


def test_cli_clean_tree_exits_zero(tmp_path, monkeypatch):
    import os

    from repro.analysis.__main__ import main

    monkeypatch.chdir(os.path.join(os.path.dirname(__file__), ".."))
    report = tmp_path / "report.json"
    rc = main(["--skip-jaxpr", "--json", str(report), "src/repro"])
    assert rc == 0
    data = json.loads(report.read_text())
    assert data["ok"] and data["lint"] == [] and data["suppressed"] > 0


def test_cli_flags_seeded_lint_finding(tmp_path):
    from repro.analysis.__main__ import main

    bad = tmp_path / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(
        "import jax.numpy as jnp\n\n"
        "def f(n):\n    return jnp.zeros((n,))\n")
    rc = main(["--skip-jaxpr", str(bad)])
    assert rc == 1
