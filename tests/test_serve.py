"""Serving correctness: prefill + decode == full forward logits for every
cache family (GQA full, GQA ring window, MLA latent, SSM state, hybrid)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.layers import lm_logits, rmsnorm
from repro.models.model import LM
from repro.serve.serve_step import generate

DECODER_ARCHS = ["phi3-mini-3.8b", "minicpm3-4b", "mamba2-370m",
                 "hymba-1.5b", "dbrx-132b", "starcoder2-15b"]


def _full_logits(m, params, tokens):
    x, positions = m._embed_inputs(params, {"tokens": tokens})
    x, _ = m._run_layers_train(params, x, positions)
    x = rmsnorm(x, params["final_norm"], m.cfg.norm_eps)
    return lm_logits(params, x, m.cfg.tie_embeddings)


@pytest.mark.parametrize("name", DECODER_ARCHS)
def test_decode_matches_full_forward(name):
    cfg = ARCHS[name].reduced()
    m = LM(cfg)
    params, _ = m.init(jax.random.PRNGKey(1))
    B, S = 2, 24
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    ref = _full_logits(m, params, toks)[:, -1, :]
    caches = m.init_caches(B, 64)
    _, caches = m.prefill(params, toks[:, : S - 3], caches)
    lg = None
    for i in range(S - 3, S):
        lg, caches = m.decode_step(params, toks[:, i: i + 1],
                                   jnp.int32(i), caches)
    err = float(jnp.max(jnp.abs(ref - lg)))
    assert err < 5e-5, f"{name}: {err}"


def test_window_ring_cache_beyond_window():
    """Decode far past the sliding window: ring buffer must agree with the
    full-forward windowed attention."""
    cfg = ARCHS["hymba-1.5b"].reduced()  # window 16, global layer 0
    m = LM(cfg)
    params, _ = m.init(jax.random.PRNGKey(2))
    B, S = 1, 40  # > 2x window
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    ref = _full_logits(m, params, toks)[:, -1, :]
    caches = m.init_caches(B, 64)
    _, caches = m.prefill(params, toks[:, :8], caches)
    lg = None
    for i in range(8, S):
        lg, caches = m.decode_step(params, toks[:, i: i + 1],
                                   jnp.int32(i), caches)
    err = float(jnp.max(jnp.abs(ref - lg)))
    assert err < 5e-5, err


def test_greedy_generate_runs():
    cfg = ARCHS["phi3-mini-3.8b"].reduced()
    m = LM(cfg)
    params, _ = m.init(jax.random.PRNGKey(3))
    prompt = jnp.zeros((2, 8), jnp.int32)
    out = generate(m, params, prompt, max_new=5, max_len=32)
    assert out.shape == (2, 5)
    assert np.all((np.asarray(out) >= 0) &
                  (np.asarray(out) < cfg.vocab_size))
