"""BFS engine parity: the hop-doubling engine and the Euler-tour tree
rooting must be BIT-IDENTICAL (depth AND parent) to the level-sync
engine and the numpy oracle across graph families — including the
padded-batch vmap path, tree-restricted masks, and disconnected
forests — and the full pipeline must produce identical sparsifiers
under either engine.

Shapes are reused across cases so the sweep costs a handful of XLA
compiles, not one per case.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import _host as H
from repro.core import baseline_sparsify, lgrass_sparsify, lgrass_sparsify_batch
from repro.core.bfs import (
    bfs,
    bfs_doubling,
    bfs_levels,
    effective_weights,
    finite_depth,
    root_tree,
    select_root,
)
from repro.core.graph import (
    Graph,
    GraphBatch,
    feeder_like_graph,
    powergrid_like_graph,
    random_connected_graph,
)


def _families(n_chain=96, seed=0):
    """One representative per family, shared across the parity tests."""
    chain = feeder_like_graph(n_chain, 0, span=4, seed=seed)  # pure chain
    feeder = feeder_like_graph(n_chain, n_chain // 2, span=8, seed=seed)
    grid = powergrid_like_graph(9, 0.3, seed=seed)
    rand = random_connected_graph(80, 180, seed=seed)
    return [("chain", chain), ("feeder", feeder), ("grid", grid),
            ("random", rand)]


def _disconnected(seed=0):
    """Two components; the BFS root lands in the larger one."""
    ga = feeder_like_graph(60, 20, span=6, seed=seed)
    gb = random_connected_graph(30, 45, seed=seed + 1)
    return Graph(
        n=90,
        u=np.concatenate([ga.u, gb.u + 60]).astype(np.int32),
        v=np.concatenate([ga.v, gb.v + 60]).astype(np.int32),
        w=np.concatenate([ga.w, gb.w]).astype(np.float32),
    )


def _assert_engines_match(g, emask=None):
    root = H.select_root_np(g.u, g.v, g.n)
    dn, pn = H.bfs_np(g.u, g.v, g.n, root, emask)
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    em = None if emask is None else jnp.asarray(emask)
    dl, pl = bfs(u, v, g.n, jnp.int32(root), em, engine="levels")
    dd, pd = bfs(u, v, g.n, jnp.int32(root), em, engine="doubling")
    assert np.array_equal(np.asarray(dl), dn)
    assert np.array_equal(np.asarray(pl), pn)
    assert np.array_equal(np.asarray(dd), dn)
    assert np.array_equal(np.asarray(pd), pn)
    return root, dn, pn


@pytest.mark.parametrize("seed", [0, 1])
def test_bfs_engines_match_oracle_all_families(seed):
    for _, g in _families(seed=seed):
        _assert_engines_match(g)


def test_bfs_unknown_engine_raises():
    g = random_connected_graph(10, 15, seed=0)
    with pytest.raises(ValueError):
        bfs(jnp.asarray(g.u), jnp.asarray(g.v), g.n, jnp.int32(0),
            engine="nope")


def test_bfs_doubling_shuffled_ids():
    """Node ids decorrelated from the chain layout: the monotone-id
    chains stop helping and the re-anchored climb must carry
    convergence — output parity is engine-independent either way."""
    g0 = feeder_like_graph(200, 120, span=10, seed=3)
    perm = np.random.default_rng(7).permutation(g0.n).astype(np.int32)
    g = Graph(n=g0.n, u=perm[g0.u], v=perm[g0.v], w=g0.w)
    _assert_engines_match(g)


def _tree_mask_from_bfs(g, root, pn):
    """A deterministic spanning-tree mask (the BFS tree itself)."""
    tmask = np.zeros(g.m, bool)
    used = np.zeros(g.n, bool)
    for i in range(g.m):
        a, b = int(g.u[i]), int(g.v[i])
        if pn[b] == a and not used[b]:
            tmask[i] = True
            used[b] = True
        elif pn[a] == b and not used[a]:
            tmask[i] = True
            used[a] = True
    return tmask


@pytest.mark.parametrize("seed", [0, 2])
def test_tree_restricted_masks_and_root_tree(seed):
    """Both engines under a tree edge mask ≡ oracle ≡ `root_tree` (the
    O(log n) Euler rooting the pipeline's second pass uses)."""
    for _, g in _families(seed=seed):
        root = H.select_root_np(g.u, g.v, g.n)
        _, pn = H.bfs_np(g.u, g.v, g.n, root)
        tmask = _tree_mask_from_bfs(g, root, pn)
        _, dt, pt = _assert_engines_match(g, tmask)
        de, pe = root_tree(
            jnp.asarray(g.u, jnp.int32), jnp.asarray(g.v, jnp.int32),
            g.n, jnp.int32(root), jnp.asarray(tmask))
        assert np.array_equal(np.asarray(de), dt)
        assert np.array_equal(np.asarray(pe), pt)


def test_disconnected_forest_parity_and_finite_weights():
    """Regression: unreachable nodes keep INF depth under every engine,
    and `effective_weights` clamps them instead of multiplying
    float32(2^31-1) into the weights (device and numpy mirror agree)."""
    g = _disconnected()
    root, dn, _ = _assert_engines_match(g)
    # exactly the non-root component is unreachable
    assert (dn == np.iinfo(np.int32).max).sum() in (30, 60)
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    w = jnp.asarray(g.w, jnp.float32)
    dd, _ = bfs_doubling(u, v, g.n, jnp.int32(root))
    eff = np.asarray(effective_weights(u, v, w, dd, g.n))
    assert np.all(np.isfinite(eff))
    assert eff.max() < 1e6  # no 2.1e9-scale poison
    eff_np = H.effective_weights_np(g.u, g.v, g.w, dn)
    assert np.array_equal(eff, eff_np)
    # unreachable component's edges degrade to eff == w (depth treated 0)
    un = (dn[g.u] == np.iinfo(np.int32).max)
    assert np.allclose(eff[un], g.w[un])
    # the shared clamp helper itself
    assert np.array_equal(
        np.asarray(finite_depth(jnp.asarray(dn))), np.where(
            dn == np.iinfo(np.int32).max, 0, dn))
    # root_tree on a spanning forest tours only the root's component
    _, pn = H.bfs_np(g.u, g.v, g.n, root)
    tmask = _tree_mask_from_bfs(g, root, pn)
    dtn, ptn = H.bfs_np(g.u, g.v, g.n, root, tmask)
    de, pe = root_tree(u, v, g.n, jnp.int32(root), jnp.asarray(tmask))
    assert np.array_equal(np.asarray(de), dtn)
    assert np.array_equal(np.asarray(pe), ptn)


def test_padded_batch_vmap_parity():
    """Both engines vmapped over a padded GraphBatch: real-slot outputs
    equal the unpadded per-graph runs; padded nodes stay unreachable."""
    graphs = [
        random_connected_graph(40, 90, seed=0),
        feeder_like_graph(50, 25, span=6, seed=1),
        powergrid_like_graph(6, 0.4, seed=2),
    ]
    batch = GraphBatch.from_graphs(graphs, n_max=64, L_max=160)
    ub = jnp.asarray(batch.u, jnp.int32)
    vb = jnp.asarray(batch.v, jnp.int32)
    evb = jnp.asarray(batch.edge_valid)
    roots = jnp.asarray(
        [H.select_root_np(g.u, g.v, g.n) for g in graphs], jnp.int32)
    for fn in (bfs_doubling, bfs_levels):
        dB, pB = jax.vmap(
            lambda a, b, r, m: fn(a, b, 64, r, m))(ub, vb, roots, evb)
        for i, g in enumerate(graphs):
            dn, pn = H.bfs_np(g.u, g.v, g.n, int(roots[i]))
            assert np.array_equal(np.asarray(dB[i])[:g.n], dn)
            assert np.array_equal(np.asarray(pB[i])[:g.n], pn)
            # padding nodes can never be reached from the real graph
            assert np.all(np.asarray(dB[i])[g.n:] == np.iinfo(np.int32).max)


def test_bfs_doubling_unpacked_key_branch():
    """n past the int32 packing bound ((n+1)^2 >= 2^31) exercises the
    two-scatter relax/witness fallback: a small graph embedded in a
    huge sparse id space, parity vs levels and the oracle."""
    n = 46_400  # (n+1)^2 > 2^31 -> packed=False
    rng = np.random.default_rng(11)
    ids = np.sort(rng.choice(n, size=600, replace=False)).astype(np.int32)
    uu = [ids[i] for i in range(599)]
    vv = [ids[i + 1] for i in range(599)]
    seen = set(zip(uu, vv))
    while len(uu) < 750:  # some long-range chords
        a, b = rng.choice(ids, 2)
        key = (min(a, b), max(a, b))
        if a == b or key in seen:
            continue
        seen.add(key)
        uu.append(key[0])
        vv.append(key[1])
    g = Graph(n=n, u=np.array(uu, np.int32), v=np.array(vv, np.int32),
              w=np.ones(len(uu), np.float32))
    _assert_engines_match(g)


def test_select_root_unchanged_by_engine_refactor():
    g = random_connected_graph(60, 140, seed=4)
    assert int(select_root(jnp.asarray(g.u, jnp.int32),
                           jnp.asarray(g.v, jnp.int32), g.n)) == \
        H.select_root_np(g.u, g.v, g.n)


@pytest.mark.parametrize("family_seed", [0, 1])
def test_pipeline_identical_under_both_engines(family_seed):
    """lgrass_sparsify(bfs_engine=...) — the whole sparsifier is
    bit-identical under either engine, and equals the baseline."""
    g = random_connected_graph(36, 80, seed=family_seed)
    base = baseline_sparsify(g, budget=7)
    for recovery in ("device", "host"):
        rd = lgrass_sparsify(g, budget=7, recovery=recovery,
                             bfs_engine="doubling")
        rl = lgrass_sparsify(g, budget=7, recovery=recovery,
                             bfs_engine="levels")
        assert np.array_equal(rd.edge_mask, rl.edge_mask)
        assert np.array_equal(rd.edge_mask, base.edge_mask)
        assert np.array_equal(rd.tree_mask, rl.tree_mask)
        assert rd.n_groups == rl.n_groups
        assert rd.n_dirty == rl.n_dirty


def test_pipeline_feeder_engine_parity():
    """The diameter-bound family the doubling engine targets."""
    g = feeder_like_graph(96, 48, span=6, seed=5)
    rd = lgrass_sparsify(g, budget=6, bfs_engine="doubling")
    rl = lgrass_sparsify(g, budget=6, bfs_engine="levels")
    assert np.array_equal(rd.edge_mask, rl.edge_mask)
    assert np.array_equal(rd.edge_mask,
                          baseline_sparsify(g, budget=6).edge_mask)


def test_batched_pipeline_engine_parity():
    graphs = [
        random_connected_graph(30, 60, seed=0),
        feeder_like_graph(50, 25, span=6, seed=1),
        powergrid_like_graph(6, 0.4, seed=2),
    ]
    rd = lgrass_sparsify_batch(graphs, budget=6, bfs_engine="doubling")
    rl = lgrass_sparsify_batch(graphs, budget=6, bfs_engine="levels")
    for g, a, b in zip(graphs, rd, rl):
        assert np.array_equal(a.edge_mask, b.edge_mask)
        assert np.array_equal(
            a.edge_mask, baseline_sparsify(g, budget=6).edge_mask)


def test_auto_lift_bound_with_doubling_engine():
    """auto_lift_bound path runs its estimate BFS through the selected
    engine and the shared finite-depth guard."""
    g = feeder_like_graph(80, 40, span=6, seed=7)
    r1 = lgrass_sparsify(g, budget=5, auto_lift_bound=True,
                         bfs_engine="doubling")
    r2 = lgrass_sparsify(g, budget=5, auto_lift_bound=False,
                         bfs_engine="levels")
    assert np.array_equal(r1.edge_mask, r2.edge_mask)
