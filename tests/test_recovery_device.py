"""Device recovery parity: the fused `lgrass_device` replay must be
BIT-IDENTICAL to the host `recover_host` oracle and to `baseline.py`
across graph families — including the overflow-dirty (k_cap=1) and
budget-exhaustion paths — and the standalone `recover_device` must agree
when driven directly from phase-1 outputs.

Shapes are deliberately reused across cases so the sweep costs a handful
of XLA compiles, not one per case (budgets are drawn from pow2-bucketed
values for the same reason).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _prop import cases, integers, sampled_from
from repro.core import (baseline_sparsify, lgrass_sparsify,
                        lgrass_sparsify_batch, recover_device,
                        recover_device_batched)
from repro.core.graph import (GraphBatch, feeder_like_graph,
                              powergrid_like_graph, random_connected_graph)
from repro.core.sparsify import (phase1_device, phase1_device_batched,
                                 phase1_views_np)


def _assert_triple(g, budget, **kw):
    """device ≡ host ≡ baseline, masks and stats."""
    base = baseline_sparsify(g, budget=budget)
    host = lgrass_sparsify(g, budget=budget, recovery="host", **kw)
    dev = lgrass_sparsify(g, budget=budget, recovery="device", **kw)
    assert np.array_equal(base.edge_mask, host.edge_mask)
    assert np.array_equal(base.edge_mask, dev.edge_mask)
    assert np.array_equal(host.tree_mask, dev.tree_mask)
    assert np.array_equal(host.accepted_mask, dev.accepted_mask)
    assert dev.n_accepted == host.n_accepted
    assert dev.n_groups == host.n_groups
    assert dev.n_overflow_groups == host.n_overflow_groups
    assert dev.n_dirty == host.n_dirty
    return dev


@pytest.mark.parametrize(
    "seed,weight,budget",
    cases(integers(0, 100_000), sampled_from(["lognormal", "ties"]),
          sampled_from([3, 7, 12]), n_cases=12, seed=31),
)
def test_device_recovery_parity_sweep(seed, weight, budget):
    g = random_connected_graph(36, 80, seed=seed, weight=weight)
    _assert_triple(g, budget)


@pytest.mark.parametrize("parallel", [True, False])
def test_device_recovery_both_schedules(parallel):
    g = random_connected_graph(45, 90, seed=1, weight="ties")
    _assert_triple(g, 8, parallel=parallel)


def test_device_recovery_powergrid():
    _assert_triple(powergrid_like_graph(6, 0.4, seed=2), 10)


@pytest.mark.parametrize("seed", [0, 3])
def test_device_recovery_feeder_noncross_heavy(seed):
    """Chain-heavy feeder graphs accept NON-crossing edges, so the
    replay's after-effects machinery does real work: the host oracle
    propagates ball dirt eagerly, the device scan derives it lazily
    (covered-by-accepted-noncross) — both must land bit-identically."""
    g = feeder_like_graph(96, 48, span=6, seed=seed)
    base = baseline_sparsify(g, budget=6)
    # the family does what it claims: non-crossing edges get accepted
    assert (~base.crossing[base.accepted]).sum() >= 1
    _assert_triple(g, 6)


def test_device_recovery_overflow_dirty():
    """k_cap=1 overflows nearly every group: device recovery must replay
    the fully-dirty groups exactly."""
    g = random_connected_graph(40, 110, seed=9)
    dev = _assert_triple(g, 20, k_cap=1)
    assert dev.n_overflow_groups > 0
    assert dev.n_dirty > 0


def test_device_recovery_budget_exhaustion():
    """Both budget cut (count hits budget) and budget excess (greedy runs
    dry before the cut) must match."""
    g = random_connected_graph(36, 80, seed=4)
    cut = _assert_triple(g, 3)
    assert cut.n_accepted == 3  # the scan's budget gate actually fired
    g2 = random_connected_graph(24, 12, seed=4)  # 12 off-tree edges
    excess = _assert_triple(g2, 20)  # budget > off-tree count
    assert excess.n_accepted < 20  # greedy ran dry below the budget


def test_device_recovery_batched_matches_host_tail():
    graphs = [
        random_connected_graph(30, 60, seed=0, weight="lognormal"),
        powergrid_like_graph(6, 0.4, seed=3),
        random_connected_graph(45, 110, seed=1, weight="ties"),
    ]
    dev = lgrass_sparsify_batch(graphs, budget=6, recovery="device")
    host = lgrass_sparsify_batch(graphs, budget=6, recovery="host")
    for g, rd, rh in zip(graphs, dev, host):
        assert np.array_equal(rd.edge_mask, rh.edge_mask)
        assert np.array_equal(
            rd.edge_mask, baseline_sparsify(g, budget=6).edge_mask
        )
        assert (rd.n_accepted, rd.n_groups, rd.n_overflow_groups,
                rd.n_dirty) == (rh.n_accepted, rh.n_groups,
                                rh.n_overflow_groups, rh.n_dirty)


def test_recover_device_standalone_from_phase1():
    """Drive `recover_device` directly from phase-1 outputs (the unit
    bench_recovery.py times) and compare against the host oracle — on
    both distance backends: the default Euler path (tables rebuilt on
    device from up[0]) and the legacy lifting climbs."""
    g = random_connected_graph(36, 80, seed=7)
    budget = 7
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    w = jnp.asarray(g.w, jnp.float32)
    d = {k: np.asarray(val)
         for k, val in phase1_device(u, v, w, g.n).items()}
    tree, crossing, accept, group, dirty0, full_order = phase1_views_np(
        d, g.m)
    want = lgrass_sparsify(g, budget=budget, recovery="host").accepted_mask

    for use_euler in (True, False):
        got, n_acc = recover_device(
            jnp.asarray(d["up"]), jnp.asarray(d["depth_t"]), u, v,
            jnp.asarray(d["beta"]), jnp.asarray(tree),
            jnp.asarray(crossing),
            jnp.asarray(full_order.astype(np.int32)), jnp.asarray(accept),
            jnp.asarray(group.astype(np.int32)), jnp.asarray(dirty0),
            jnp.int32(budget), b_cap=8, use_euler_lca=use_euler,
        )
        assert np.array_equal(np.asarray(got), want), use_euler
        assert int(n_acc) == int(want.sum())


def test_recover_device_batched_standalone_euler_parity():
    """`recover_device_batched` driven from batched phase-1 outputs:
    each lane rebuilds its own Euler tables from up[0] (the ROADMAP
    'standalone recovery still climbs the lifting tables' fix) and must
    agree with the per-graph host oracle on every lane — padded shapes
    and all — and with the lifting backend bit for bit."""
    graphs = [
        feeder_like_graph(80, 40, span=6, seed=11),
        random_connected_graph(45, 110, seed=12, weight="ties"),
        powergrid_like_graph(6, 0.4, seed=13),
    ]
    batch = GraphBatch.from_graphs(graphs)
    budgets = [6, 9, 5]
    d = {k: np.asarray(val) for k, val in phase1_device_batched(
        jnp.asarray(batch.u, jnp.int32), jnp.asarray(batch.v, jnp.int32),
        jnp.asarray(batch.w, jnp.float32),
        jnp.asarray(batch.edge_valid), batch.n_max).items()}
    L_pad = batch.L_max
    tree = np.zeros((len(graphs), L_pad), bool)
    crossing = np.zeros((len(graphs), L_pad), bool)
    accept = np.zeros((len(graphs), L_pad), bool)
    group = np.full((len(graphs), L_pad), -1, np.int32)
    dirty0 = np.zeros((len(graphs), L_pad), bool)
    order = np.zeros((len(graphs), L_pad), np.int32)
    for i in range(len(graphs)):
        di = {k: val[i] for k, val in d.items()}
        # phase1_views_np over the PADDED length: the padded tail sorts
        # after every real slot, exactly what the device glue sees
        t_, c_, a_, g_, dd_, o_ = phase1_views_np(di, L_pad)
        tree[i], crossing[i], accept[i] = t_, c_, a_
        group[i], dirty0[i], order[i] = g_, dd_, o_.astype(np.int32)

    outs = {}
    for use_euler in (True, False):
        got, cnt = recover_device_batched(
            jnp.asarray(d["up"]), jnp.asarray(d["depth_t"]),
            jnp.asarray(batch.u, jnp.int32),
            jnp.asarray(batch.v, jnp.int32),
            jnp.asarray(d["beta"]), jnp.asarray(tree),
            jnp.asarray(crossing), jnp.asarray(order),
            jnp.asarray(accept), jnp.asarray(group), jnp.asarray(dirty0),
            jnp.asarray(np.asarray(budgets, np.int32)), b_cap=16,
            edge_valid=jnp.asarray(batch.edge_valid),
            use_euler_lca=use_euler,
        )
        outs[use_euler] = (np.asarray(got), np.asarray(cnt))
    assert np.array_equal(outs[True][0], outs[False][0])
    assert np.array_equal(outs[True][1], outs[False][1])
    for i, (g, b) in enumerate(zip(graphs, budgets)):
        want = lgrass_sparsify(g, budget=b, recovery="host").accepted_mask
        assert np.array_equal(outs[True][0][i][: g.m], want), i
        assert int(outs[True][1][i]) == int(want.sum())
        assert not outs[True][0][i][g.m:].any()  # padding never accepted


def test_feeder_like_graph_clamps_unreachable_chords():
    """Chord requests beyond the span-reachable pair count must clamp,
    not spin the rejection loop forever."""
    g = feeder_like_graph(50, 10_000, span=5, seed=0)
    g.validate()
    assert g.m - (g.n - 1) == sum(50 - d for d in range(2, 6))


def test_recover_device_budget_clamped_to_b_cap():
    """The traced-budget precondition (b_cap >= budget) cannot raise in
    jit; the scan clamps instead, yielding the exact b_cap-budget replay
    rather than a corrupted buffer."""
    g = random_connected_graph(36, 80, seed=2)
    over = lgrass_sparsify(g, budget=4, recovery="host")
    d = {k: np.asarray(val) for k, val in phase1_device(
        jnp.asarray(g.u, jnp.int32), jnp.asarray(g.v, jnp.int32),
        jnp.asarray(g.w, jnp.float32), g.n).items()}
    tree, crossing, accept, group, dirty0, order = phase1_views_np(d, g.m)
    got, n_acc = recover_device(
        jnp.asarray(d["up"]), jnp.asarray(d["depth_t"]),
        jnp.asarray(g.u, jnp.int32), jnp.asarray(g.v, jnp.int32),
        jnp.asarray(d["beta"]), jnp.asarray(tree), jnp.asarray(crossing),
        jnp.asarray(order.astype(np.int32)), jnp.asarray(accept),
        jnp.asarray(group.astype(np.int32)), jnp.asarray(dirty0),
        jnp.int32(9), b_cap=4,  # budget 9 > b_cap 4 -> clamped to 4
    )
    assert np.array_equal(np.asarray(got), over.accepted_mask)
    assert int(n_acc) == over.n_accepted


def test_device_recovery_tree_kernel_parity():
    """The Pallas tree-distance kernel path (interpret mode on CPU) is
    bit-identical inside the fused program."""
    g = random_connected_graph(24, 40, seed=5)
    host = lgrass_sparsify(g, budget=5, recovery="host")
    dev = lgrass_sparsify(g, budget=5, recovery="device",
                          use_tree_kernel=True)
    assert np.array_equal(host.edge_mask, dev.edge_mask)
