"""Spectral quality at 10^5–10^6 nodes, judged entirely solver-free.

tests/test_spectral_quality.py pins quality against the dense pinv — an
O(n³) oracle that dies around 10⁴ nodes. This tier runs the same
*judgement* at sizes the paper targets, scoring sparsifiers with the
probe estimator (`core/spectral_probe.py`, calibrated against that very
oracle in tests/test_spectral_probe.py): trace similarity
tr(L_G⁺ L_H) = Σ_{e∈H} w_e R̂_G(e), where larger = spectrally closer to
G and the full graph scores ≈ n − 1. No dense Laplacian is ever
materialised here.

Assertions, per graph family (chain+chords / feeder / grid / random):

  * every per-edge estimate is finite at n = LGRASS_SCALE_N;
  * score(tree) < score(LGRASS sparsifier) ≤ score(full graph) — the
    accepted chords buy real spectral mass;
  * score(LGRASS) > mean score of seeded random-chord controls (same
    tree, same #accepted, chords drawn uniformly) — the criticality
    ordering beats blind acceptance (measured margins +3.3..+9.1 trace
    units at n = 10⁵, against control-draw noise well under that);
  * score is monotone in budget (accepted sets are prefix-monotone in
    the criticality order, so this is exact, not statistical);
  * on families where the numpy oracle's O(diameter·L) BFS is feasible
    (grid, random — NOT the diameter-10⁵ chain/feeder), the device
    mask still bit-matches `baseline_sparsify`.

Budgets here are deliberately lean (P = 16 probes, k = 32 rounds —
rank-level, not value-level, accuracy): CI pays ~45 s for the whole
10⁵ tier. The 10⁶ variants run the same checks at P = 8 and are marked
`slow` (excluded from tier-1; enable with --run-slow).

LGRASS_SCALE_N overrides the tier size (default 100_000).
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.baseline import baseline_sparsify
from repro.core.graph import (Graph, feeder_like_graph,
                              powergrid_like_graph,
                              random_connected_graph)
from repro.core.sparsify import lgrass_sparsify
from repro.core.spectral_probe import probe_edge_resistance, trace_similarity

SCALE_N = int(os.environ.get("LGRASS_SCALE_N", "100000"))
BUDGET = 48
B_CAP = 64
N_PROBES = 16
N_ITERS = 32


def chain_with_chords(n: int, chords: int, seed: int = 0) -> Graph:
    """A path 0–1–…–(n−1) plus ~`chords` random long-range chords,
    built fully vectorised (no python loop survives 10⁶ nodes)."""
    rng = np.random.default_rng(seed)
    cu = np.arange(n - 1, dtype=np.int64)
    a = rng.integers(0, n, chords)
    b = rng.integers(0, n, chords)
    keep = a != b
    lo = np.minimum(a, b)[keep]
    hi = np.maximum(a, b)[keep]
    key = np.unique(lo * np.int64(n) + hi)  # dedupe chords
    lo, hi = key // n, key % n
    far = hi != lo + 1                      # drop chords shadowing the chain
    u = np.concatenate([cu, lo[far]]).astype(np.int32)
    v = np.concatenate([cu + 1, hi[far]]).astype(np.int32)
    w = rng.lognormal(0.0, 1.0, len(u)).astype(np.float32)
    return Graph(n=n, u=u, v=v, w=w)


def _families(n: int):
    side = max(2, int(round(n ** 0.5)))
    return {
        "chain": lambda: chain_with_chords(n, max(64, n // 32), seed=1),
        "feeder": lambda: feeder_like_graph(n, max(64, n // 50),
                                            span=24, seed=1),
        "grid": lambda: powergrid_like_graph(side, 0.25, seed=1),
        "random": lambda: random_connected_graph(n, n, seed=1),
    }


def _scores(g: Graph, n_probes: int, n_iters: int):
    """(result, r̂, score_tree, score_lgrass, score_full, mean ctrl)."""
    res = lgrass_sparsify(g, budget=BUDGET, b_cap=B_CAP)
    r_hat = np.asarray(probe_edge_resistance(
        g.u, g.v, g.w, g.n, n_probes=n_probes, n_iters=n_iters, seed=2))
    assert np.isfinite(r_hat).all()
    assert (r_hat >= 0.0).all()
    wj = jnp.asarray(g.w)
    rj = jnp.asarray(r_hat)
    s_tree = float(trace_similarity(wj, rj, jnp.asarray(res.tree_mask)))
    s_lgr = float(trace_similarity(wj, rj, jnp.asarray(res.edge_mask)))
    s_full = float(trace_similarity(wj, rj))
    rng = np.random.default_rng(7)
    off_idx = np.flatnonzero(~res.tree_mask)
    ctrls = []
    for _ in range(5):
        pick = rng.choice(off_idx, size=res.n_accepted, replace=False)
        ctrl = res.tree_mask.copy()
        ctrl[pick] = True
        ctrls.append(float(trace_similarity(wj, rj, jnp.asarray(ctrl))))
    return res, r_hat, s_tree, s_lgr, s_full, float(np.mean(ctrls))


@pytest.mark.parametrize("family", ["chain", "feeder", "grid", "random"])
def test_scale_quality(family):
    g = _families(SCALE_N)[family]()
    res, r_hat, s_tree, s_lgr, s_full, s_ctrl = _scores(
        g, N_PROBES, N_ITERS)
    assert res.n_accepted == BUDGET
    # chords buy spectral mass; the sparsifier never exceeds the graph
    assert s_tree < s_lgr <= s_full
    # criticality-ordered acceptance beats blind acceptance
    assert s_lgr > s_ctrl
    # exact (not statistical): smaller budget ⊂ larger budget
    small = lgrass_sparsify(g, budget=16, b_cap=B_CAP)
    assert (small.accepted_mask <= res.accepted_mask).all()
    wj, rj = jnp.asarray(g.w), jnp.asarray(r_hat)
    s_small = float(trace_similarity(wj, rj, jnp.asarray(small.edge_mask)))
    assert s_small <= s_lgr


@pytest.mark.parametrize("family", ["grid", "random"])
def test_scale_matches_numpy_oracle(family):
    """The device pipeline stays bit-identical to the numpy greedy at
    scale. Grid/random only: the oracle's level-by-level BFS is
    O(diameter·L) — ~1 s on diameter-√n families, unusable on the
    diameter-n chain and feeder."""
    g = _families(SCALE_N)[family]()
    res = lgrass_sparsify(g, budget=BUDGET, b_cap=B_CAP)
    ref = baseline_sparsify(g, budget=BUDGET)
    np.testing.assert_array_equal(res.edge_mask, ref.edge_mask)


@pytest.mark.slow
@pytest.mark.parametrize("family", ["chain", "random"])
def test_scale_quality_1e6(family):
    g = _families(1_000_000)[family]()
    res, _, s_tree, s_lgr, s_full, s_ctrl = _scores(g, 8, N_ITERS)
    assert res.n_accepted == BUDGET
    assert s_tree < s_lgr <= s_full
    assert s_lgr > s_ctrl
