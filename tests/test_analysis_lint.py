"""AST lint rules (`analysis.lint`): per-rule positives and negatives
on synthetic sources, baseline suppression semantics, and the
repo-level contract that the live tree is clean modulo the baseline."""
import json
import os
import textwrap

import pytest

from repro.analysis.lint import (
    Finding,
    RULES,
    apply_baseline,
    lint_file,
    load_baseline,
    run_lint,
)


def _lint_src(tmp_path, source, fname="core/mod.py"):
    path = tmp_path / fname
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    # relpath keeps the "core/" component so device-path scoping applies
    return lint_file(str(path), fname)


def _rules(findings):
    return [f.rule for f in findings]


# ------------------------------------------------------------------ ANA001

def test_ana001_flags_mixed_numpy_jnp(tmp_path):
    fs = _lint_src(tmp_path, """
        import numpy as np
        import jax.numpy as jnp

        def mixed(x):
            y = jnp.cumsum(x)
            return np.asarray(y) + 1
    """)
    assert _rules(fs) == ["ANA001"]
    assert fs[0].symbol == "mixed"


def test_ana001_pure_numpy_helper_is_clean(tmp_path):
    fs = _lint_src(tmp_path, """
        import numpy as np

        def host_helper(x):
            return np.asarray(x) + 1
    """)
    assert fs == []


def test_ana001_name_convention_exempt(tmp_path):
    fs = _lint_src(tmp_path, """
        import numpy as np
        import jax.numpy as jnp

        def decode_np(x):
            return np.asarray(jnp.cumsum(x))
    """)
    assert fs == []


def test_ana001_not_applied_outside_device_paths(tmp_path):
    fs = _lint_src(tmp_path, """
        import numpy as np
        import jax.numpy as jnp

        def mixed(x):
            return np.asarray(jnp.cumsum(x))
    """, fname="train/mod.py")
    assert fs == []


# ------------------------------------------------------------------ ANA002

def test_ana002_flags_unpinned_zeros(tmp_path):
    fs = _lint_src(tmp_path, """
        import jax.numpy as jnp

        def f(n):
            return jnp.zeros((n,))
    """)
    assert _rules(fs) == ["ANA002"]


def test_ana002_accepts_positional_and_keyword_dtype(tmp_path):
    fs = _lint_src(tmp_path, """
        import jax.numpy as jnp

        def f(n):
            a = jnp.zeros((n,), jnp.int32)
            b = jnp.ones((n,), dtype=jnp.float32)
            c = jnp.full((n,), -1, jnp.int32)
            return a, b, c
    """)
    assert fs == []


def test_ana002_full_literal_fill_flags_name_fill_passes(tmp_path):
    fs = _lint_src(tmp_path, """
        import jax.numpy as jnp

        INF = jnp.float32(3e38)

        def f(n):
            bad = jnp.full((n,), 0)
            ok = jnp.full((n,), INF)
            return bad, ok
    """)
    assert _rules(fs) == ["ANA002"]


# ------------------------------------------------------------------ ANA003

def test_ana003_flags_host_sync(tmp_path):
    fs = _lint_src(tmp_path, """
        import jax

        def decode(x):
            return jax.device_get(x)
    """)
    assert _rules(fs) == ["ANA003"]


def test_ana003_flags_block_until_ready(tmp_path):
    fs = _lint_src(tmp_path, """
        def wait(x):
            return x.block_until_ready()
    """, fname="serve/mod.py")
    assert _rules(fs) == ["ANA003"]


# ------------------------------------------------------------------ ANA004

def test_ana004_flags_missing_mask(tmp_path):
    fs = _lint_src(tmp_path, """
        def sparsify(u, v, w, n):
            return u
    """)
    assert _rules(fs) == ["ANA004"]
    assert fs[0].symbol == "sparsify"


def test_ana004_mask_param_and_private_pass(tmp_path):
    fs = _lint_src(tmp_path, """
        def sparsify(u, v, w, n, edge_valid):
            return u

        def _internal(u, v, w, n):
            return u

        def oracle_numpy(u, v, w, n):
            return u
    """)
    assert fs == []


# ------------------------------------------------------------------ ANA005

def test_ana005_flags_callbacks(tmp_path):
    fs = _lint_src(tmp_path, """
        import jax

        def f(x):
            jax.debug.print("x={}", x)
            return jax.pure_callback(lambda a: a, x, x)
    """)
    assert sorted(_rules(fs)) == ["ANA005", "ANA005"]


# ---------------------------------------------------------------- baseline

def test_baseline_suppression_by_symbol_and_wildcard():
    f1 = Finding("ANA003", "src/repro/core/x.py", 10, "decode", "m")
    f2 = Finding("ANA003", "src/repro/core/x.py", 20, "other", "m")
    f3 = Finding("ANA001", "src/repro/core/x.py", 30, "decode", "m")
    base = [{"rule": "ANA003", "path": "src/repro/core/x.py",
             "symbol": "decode", "reason": "r"}]
    new, sup = apply_baseline([f1, f2, f3], base)
    assert sup == [f1] and new == [f2, f3]
    wild = [{"rule": "ANA003", "path": "src/repro/core/x.py",
             "symbol": "*", "reason": "r"}]
    new, sup = apply_baseline([f1, f2, f3], wild)
    assert new == [f3] and len(sup) == 2


def test_shipped_baseline_entries_all_documented():
    for entry in load_baseline():
        assert entry["rule"] in RULES
        assert entry.get("reason"), f"baseline entry without reason: {entry}"


def test_repo_tree_clean_modulo_baseline():
    """THE contract tier1-static enforces: the live source tree has no
    findings beyond the reviewed baseline."""
    root = os.path.join(os.path.dirname(__file__), "..")
    cwd = os.getcwd()
    os.chdir(root)
    try:
        findings = run_lint(["src/repro"])
        new, _ = apply_baseline(findings, load_baseline())
    finally:
        os.chdir(cwd)
    assert new == [], "\n".join(f.format() for f in new)
