"""The assigned architecture table, asserted literally."""
import pytest

from repro.configs import ARCHS, SHAPES, cell_skip_reason

# (layers, d_model, heads, kv, d_ff, vocab) per the assignment
EXPECTED = {
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
    "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
    "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
    "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
}


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_exact_assigned_dims(name):
    c = ARCHS[name]
    l, d, h, kv, ff, v = EXPECTED[name]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.d_ff, c.vocab_size) == (l, d, h, kv, ff, v)


def test_special_fields():
    assert ARCHS["mamba2-370m"].ssm_state == 128
    assert ARCHS["hymba-1.5b"].ssm_state == 16
    assert ARCHS["dbrx-132b"].n_experts == 16
    assert ARCHS["dbrx-132b"].moe_top_k == 4
    assert ARCHS["granite-moe-3b-a800m"].n_experts == 40
    assert ARCHS["granite-moe-3b-a800m"].moe_top_k == 8
    assert ARCHS["hubert-xlarge"].is_encoder
    assert ARCHS["minicpm3-4b"].attn_type == "mla"
    assert ARCHS["hymba-1.5b"].sliding_window == 1024


def test_padding_for_tp16():
    h = ARCHS["hymba-1.5b"].padded_for_mesh(16)
    assert h.n_heads == 32 and h.n_heads % h.n_kv_heads == 0
    assert h.real_n_heads == 25
    assert h.vocab_size % 16 == 0 and h.real_vocab_size == 32001
    g = ARCHS["granite-moe-3b-a800m"].padded_for_mesh(16)
    assert g.n_experts == 48 and g.real_n_experts == 40
    m = ARCHS["minicpm3-4b"].padded_for_mesh(16)
    assert m.n_heads == 48 and m.real_n_heads == 40
    p = ARCHS["phi3-mini-3.8b"].padded_for_mesh(16)
    assert p.n_heads == 32 and p.real_n_heads == 0  # no padding needed


def test_skip_rules():
    # long_500k: only SSM/hybrid run it
    runs_long = [n for n, c in ARCHS.items()
                 if cell_skip_reason(c, SHAPES["long_500k"]) is None]
    assert sorted(runs_long) == ["hymba-1.5b", "mamba2-370m"]
    # encoder has no decode
    assert cell_skip_reason(ARCHS["hubert-xlarge"], SHAPES["decode_32k"])
    assert cell_skip_reason(ARCHS["hubert-xlarge"], SHAPES["long_500k"])
    # everyone trains
    for c in ARCHS.values():
        assert cell_skip_reason(c, SHAPES["train_4k"]) is None


def test_param_counts_match_nameplates():
    # within 15% of the nameplate (naming conventions vary)
    plates = {"mamba2-370m": 0.37e9, "chameleon-34b": 34e9,
              "hymba-1.5b": 1.52e9, "starcoder2-15b": 15e9,
              "phi3-mini-3.8b": 3.8e9, "minicpm3-4b": 4e9,
              "internlm2-20b": 20e9, "hubert-xlarge": 0.96e9,
              "dbrx-132b": 132e9, "granite-moe-3b-a800m": 3.3e9}
    for name, plate in plates.items():
        got = ARCHS[name].n_params()
        assert abs(got - plate) / plate < 0.15, (name, got, plate)
