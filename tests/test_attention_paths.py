"""Attention path equivalences: banded SWA and chunked-prefill paths must
match the dense masked reference exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (_banded_swa, _masked_softmax_attend,
                                    ATTN_CHUNK, gqa_attention)


@pytest.mark.parametrize("b,s,h,kv,d,w", [
    (2, 128, 4, 2, 16, 32),
    (1, 96, 2, 1, 8, 16),
    (2, 64, 4, 4, 32, 32),
    (1, 256, 8, 2, 8, 64),
])
def test_banded_swa_matches_dense(b, s, h, kv, d, w):
    rng = np.random.default_rng(s + w)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    banded = _banded_swa(q, k, v, pos, kv, d ** -0.5, w)
    dense = _masked_softmax_attend(q, k, v, kv, d ** -0.5, pos, pos,
                                   True, w)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


def test_chunked_prefill_matches_dense(monkeypatch):
    """Force the q-chunked path at small sizes and compare."""
    import repro.models.attention as A
    monkeypatch.setattr(A, "ATTN_CHUNK_THRESHOLD", 64)
    monkeypatch.setattr(A, "ATTN_CHUNK", 32)
    from repro.configs import ARCHS
    from repro.models.layers import ParamSet
    cfg = ARCHS["phi3-mini-3.8b"].reduced()
    ps = ParamSet()
    A.init_gqa(ps, jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 128, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(128), (2, 128))
    chunked = A.gqa_attention(ps.values, cfg, x, pos, causal=True)
    monkeypatch.setattr(A, "ATTN_CHUNK_THRESHOLD", 8192)
    dense = A.gqa_attention(ps.values, cfg, x, pos, causal=True)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


def test_hlo_analyzer_trip_counts():
    """The roofline instrument itself: scan flops must be trip-scaled."""
    from repro.launch.hlo_analysis import analyze

    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    r = analyze(c.as_text())
    assert r["flops"] == 6 * 2 * 64 * 128 * 128
    assert r["mem_bytes_dots"] > 0
    assert r["mem_bytes"] <= r["mem_bytes_upper"] + 1e-6
