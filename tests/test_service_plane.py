"""The serving plane (PR 6): async dispatch, buffer donation, batch-axis
sharding, staging-pool reuse, on-path-compile accounting, padding-stat
split, and the trivial-graph (edgeless / single-node) service path.

The load-bearing contract: every serving mode — sync, async, donated,
sharded — returns results in request order, bit-identical to per-graph
`lgrass_sparsify`, across mixed sizes, explicit+None budgets, chunk
boundaries, and placeholder tails.
"""
import jax
import numpy as np
import pytest

from repro.core import lgrass_sparsify, lgrass_sparsify_batch
from repro.core.baseline import default_budget
from repro.core.distributed import batch_mesh, mesh_size
from repro.core.graph import (GraphBatch, powergrid_like_graph,
                              random_connected_graph, trivial_graph)
from repro.serve.sparsify_service import ServiceStats, SparsifyService

MULTIDEV = len(jax.devices()) >= 2


def _mixed_graphs():
    """Mixed sizes/families across several pow2 buckets, with trivial
    (edgeless) requests interleaved mid-stream."""
    gs = [
        random_connected_graph(30, 60, seed=0, weight="lognormal"),
        random_connected_graph(45, 110, seed=1, weight="ties"),
        powergrid_like_graph(6, 0.4, seed=3),
        trivial_graph(),
        random_connected_graph(24, 40, seed=2),
        random_connected_graph(18, 25, seed=7),
        trivial_graph(),
        random_connected_graph(40, 95, seed=5, weight="ties"),
    ]
    budgets = [8, None, 5, None, 3, None, 2, 7]
    return gs, budgets


def _reference(graphs, budgets):
    return [
        lgrass_sparsify(g, budget=b, parallel=False) if g.m else None
        for g, b in zip(graphs, budgets)
    ]


def _assert_request_order_parity(graphs, budgets, results, ref):
    assert len(results) == len(graphs)
    for k, (g, r) in enumerate(zip(graphs, results)):
        if g.m == 0:
            assert r.edge_mask.shape == (0,), k
            assert r.tree_mask.shape == (0,), k
            assert r.accepted_mask.shape == (0,), k
            assert r.n_accepted == 0, k
        else:
            assert np.array_equal(r.edge_mask, ref[k].edge_mask), k
            assert np.array_equal(r.tree_mask, ref[k].tree_mask), k
            assert np.array_equal(r.accepted_mask, ref[k].accepted_mask), k
            assert r.n_accepted == ref[k].n_accepted, k


# ------------------------------------------------------------------ modes

@pytest.mark.parametrize("mode", ["sync", "async", "async_donate"])
def test_service_mode_parity(mode):
    """Mixed sizes, explicit+None budgets, chunk boundaries (chunks of
    3), and placeholder tails stay bit-identical to per-graph runs for
    every serving mode — including on a SECOND call, which exercises
    staging-pool reuse in steady state."""
    graphs, budgets = _mixed_graphs()
    ref = _reference(graphs, budgets)
    svc = SparsifyService(
        parallel=False, max_batch_size=3,
        async_dispatch=(mode != "sync"),
        donate=(mode == "async_donate"),
    )
    for _ in range(2):
        results = svc.sparsify(graphs, budget=budgets)
        _assert_request_order_parity(graphs, budgets, results, ref)


@pytest.mark.skipif(not MULTIDEV, reason="needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
@pytest.mark.parametrize("mode", ["sync", "async_donate"])
def test_service_sharded_parity(mode):
    """Batch-axis sharding across the mesh is invisible in the results:
    bit-identical to per-graph runs, composing with async + donation."""
    graphs, budgets = _mixed_graphs()
    ref = _reference(graphs, budgets)
    svc = SparsifyService(
        parallel=False, max_batch_size=4, mesh=batch_mesh(),
        async_dispatch=(mode != "sync"), donate=(mode == "async_donate"),
    )
    for _ in range(2):
        results = svc.sparsify(graphs, budget=budgets)
        _assert_request_order_parity(graphs, budgets, results, ref)


@pytest.mark.skipif(not MULTIDEV, reason="needs >= 2 devices")
def test_service_sharded_pad_batch_mesh_multiple():
    """With a mesh, the batch pad target is a whole multiple of the mesh
    size so every shard gets equal rows."""
    mesh = batch_mesh()
    ms = mesh_size(mesh)
    svc = SparsifyService(parallel=False, mesh=mesh)
    for n_chunk in (1, 2, ms - 1, ms, ms + 1, 3 * ms):
        B = svc._pad_batch(n_chunk)
        assert B >= n_chunk and B % ms == 0, (n_chunk, B)


def test_service_single_device_mesh_path():
    """mesh=batch_mesh(1) runs the sharded code path on one device —
    results identical, pad target unchanged (pow2)."""
    graphs, budgets = _mixed_graphs()
    ref = _reference(graphs, budgets)
    svc = SparsifyService(parallel=False, mesh=batch_mesh(1),
                          async_dispatch=True)
    results = svc.sparsify(graphs, budget=budgets)
    _assert_request_order_parity(graphs, budgets, results, ref)
    assert svc._pad_batch(3) == 4


def test_host_recovery_rejects_serving_modes():
    """The host oracle tail blocks per chunk by design; the serving-plane
    modes require the fused device program."""
    for kw in (dict(async_dispatch=True), dict(donate=True),
               dict(mesh=batch_mesh(1))):
        with pytest.raises(ValueError):
            SparsifyService(recovery="host", **kw)
    with pytest.raises(ValueError):
        SparsifyService(recovery="nope")
    # plain host mode still serves
    g = random_connected_graph(20, 30, seed=3)
    svc = SparsifyService(parallel=False, recovery="host")
    [r] = svc.sparsify([g], budget=4)
    assert np.array_equal(
        r.edge_mask,
        lgrass_sparsify(g, budget=4, parallel=False,
                        recovery="host").edge_mask,
    )


# -------------------------------------------------- trivial-graph bugfix

def test_trivial_graph_direct_and_batched():
    """Edgeless / single-node graphs return empty masks through the
    direct API and the batched path (L_max == 0 program)."""
    g1 = trivial_graph()
    import dataclasses
    g5 = dataclasses.replace(trivial_graph(), n=5)  # isolated nodes
    for g in (g1, g5):
        r = lgrass_sparsify(g, parallel=False)
        assert r.edge_mask.shape == (0,) and r.n_accepted == 0
    batch = GraphBatch.from_graphs([g1, g5])
    assert batch.L_max == 0
    for r in lgrass_sparsify_batch(batch, parallel=False):
        assert r.edge_mask.shape == (0,) and r.n_accepted == 0


def test_trivial_graph_service_regression():
    """The service path: edgeless requests bucket through next_pow2(0)
    and the device m==0 guards without crashing, mixed with real
    traffic, empty request lists, and — the regression — small buckets
    whose placeholder must be the (n=1, m=0) trivial graph (the old
    (n=2, m=1) filler crashed min_n_bucket=1 buckets with
    'bucket too small')."""
    svc = SparsifyService(parallel=False)
    assert svc.sparsify([]) == []

    g = random_connected_graph(20, 30, seed=1)
    ref = lgrass_sparsify(g, budget=5, parallel=False)
    results = svc.sparsify([trivial_graph(), g, trivial_graph()],
                           budget=[None, 5, None])
    assert results[0].edge_mask.shape == (0,)
    assert results[2].edge_mask.shape == (0,)
    assert np.array_equal(results[1].edge_mask, ref.edge_mask)

    # the placeholder-fill regression: 3 trivial graphs in a (1, 1)
    # bucket force a placeholder row into the smallest possible bucket
    svc_min = SparsifyService(parallel=False, min_n_bucket=1,
                              min_L_bucket=1)
    out = svc_min.sparsify([trivial_graph()] * 3)
    assert [r.edge_mask.shape for r in out] == [(0,)] * 3
    # warmup accepts trivial sizes too
    assert svc_min.warmup([(1, 0)]) == 1


# ------------------------------------------------------- stats: padding

def test_padding_overhead_split_pinned():
    """batch_pad (placeholder rows) vs shape_pad (real rows' tail) on a
    known request set, pinned exactly.

    Set: 3x (n=20, m=49) -> bucket (32, 64), one chunk padded B=4
         1x (n=40, m=109) -> bucket (64, 128), one chunk of B=1
    """
    graphs = [random_connected_graph(20, 30, seed=s) for s in range(3)]
    graphs.append(random_connected_graph(40, 70, seed=9))
    assert [g.m for g in graphs] == [49, 49, 49, 109]
    svc = SparsifyService(parallel=False)
    svc.sparsify(graphs, budget=4)
    s = svc.stats
    assert s.n_dispatches == 2
    assert s.bucket_counts == {(32, 64): 3, (64, 128): 1}
    assert s.n_padded_edge_slots == 4 * 64 + 1 * 128          # 384
    assert s.n_real_edge_slots == 3 * 49 + 109                # 256
    assert s.n_batch_pad_edge_slots == 1 * 64                 # 1 filler row
    assert s.n_shape_pad_edge_slots == (3 * 64 - 147) + (128 - 109)  # 64
    assert s.batch_pad_overhead == pytest.approx(64 / 384)
    assert s.shape_pad_overhead == pytest.approx(64 / 384)
    # the two kinds are disjoint and account for every non-real slot
    assert s.padding_overhead == pytest.approx((64 + 64) / 384)
    assert (s.n_real_edge_slots + s.n_batch_pad_edge_slots
            + s.n_shape_pad_edge_slots) == s.n_padded_edge_slots


def test_padding_overhead_empty_stats():
    s = ServiceStats()
    assert s.padding_overhead == 0.0
    assert s.batch_pad_overhead == 0.0
    assert s.shape_pad_overhead == 0.0


# -------------------------------------------- stats: on-path compiles

def test_on_path_compile_accounting():
    """warmup covering the traffic's dispatch signatures => zero on-path
    compiles; a request whose explicit budget exceeds the bucket default
    widens b_cap into a program warmup never compiled => counted ONCE."""
    graphs = [random_connected_graph(20, 30, seed=s) for s in range(3)]
    svc = SparsifyService(parallel=False)
    svc.warmup([(graphs[0].n, graphs[0].m)],   # B_pad 4, default b_cap
               batch_sizes=(3,))
    res = svc.sparsify(graphs)                 # one chunk of 3 -> B=4
    assert svc.stats.n_on_path_compiles == 0
    assert all(r is not None for r in res)

    # explicit budget 30 > default_budget(32) = 2: b_cap widens 8 -> 32
    svc.sparsify([graphs[0]], budget=30)
    assert svc.stats.n_on_path_compiles == 1
    svc.sparsify([graphs[0]], budget=30)       # same signature: not recounted
    assert svc.stats.n_on_path_compiles == 1

    # warming the wide-budget program up front keeps the path clean
    svc2 = SparsifyService(parallel=False)
    svc2.warmup([(graphs[0].n, graphs[0].m)], batch_sizes=(1, 3),
                budgets=[30])
    svc2.sparsify(graphs, budget=30)
    svc2.sparsify([graphs[0]], budget=30)
    assert svc2.stats.n_on_path_compiles == 0


def test_warmup_warms_the_traffic_program_variant():
    """warmup goes through the SAME dispatch funnel as traffic, so the
    donated program (a distinct jit cache) is what gets compiled when
    donate=True."""
    from repro.core.sparsify import (lgrass_device_batched,
                                     lgrass_device_batched_donated)

    g = random_connected_graph(20, 30, seed=3)
    svc = SparsifyService(parallel=False, async_dispatch=True, donate=True)
    before_plain = lgrass_device_batched._cache_size()
    before_don = lgrass_device_batched_donated._cache_size()
    svc.warmup([(g.n, g.m)])
    assert lgrass_device_batched._cache_size() == before_plain
    assert lgrass_device_batched_donated._cache_size() == before_don + 1
    [r] = svc.sparsify([g])
    assert lgrass_device_batched_donated._cache_size() == before_don + 1
    assert svc.stats.n_on_path_compiles == 0
    assert np.array_equal(
        r.edge_mask, lgrass_sparsify(g, parallel=False).edge_mask)


# ------------------------------------------------------- staging pool

def test_staging_pool_steady_state_no_growth():
    """The fence-guarded pool grows only while dispatches are in flight;
    repeat traffic reuses the same buffer sets (zero-alloc steady
    state), and results stay exact throughout."""
    graphs, budgets = _mixed_graphs()
    ref = _reference(graphs, budgets)
    svc = SparsifyService(parallel=False, max_batch_size=3,
                          async_dispatch=True, donate=True)
    _assert_request_order_parity(
        graphs, budgets, svc.sparsify(graphs, budget=budgets), ref)
    sets_after_first = svc._pool.n_buffer_sets
    for _ in range(3):
        _assert_request_order_parity(
            graphs, budgets, svc.sparsify(graphs, budget=budgets), ref)
    assert svc._pool.n_buffer_sets <= sets_after_first + 1


def test_async_budget_isolation_across_chunks():
    """Regression for the staging race: chunks of the SAME bucket carry
    different budgets; with async dispatch the later chunk's staging
    refill must not leak into the earlier in-flight dispatch."""
    graphs = [random_connected_graph(20, 30, seed=s) for s in range(6)]
    budgets = [2, 3, 4, 5, 6, 7]
    svc = SparsifyService(parallel=False, max_batch_size=2,
                          async_dispatch=True)
    for _ in range(2):
        results = svc.sparsify(graphs, budget=budgets)
        for g, b, r in zip(graphs, budgets, results):
            single = lgrass_sparsify(g, budget=b, parallel=False)
            assert np.array_equal(r.edge_mask, single.edge_mask), b
            assert r.n_accepted == single.n_accepted, b
