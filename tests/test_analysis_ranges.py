"""Interval range propagator (`analysis.ranges`): the machine-checked
side of every "fits int32" comment in the pipeline. Covers interval
arithmetic with sentinels, the packed-key bound derivation against the
runtime constants, per-op overflow localization on synthetic jaxprs,
and the PR 5 unclamped-INF-depth regression caught statically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.ranges import (
    INT32_MAX,
    Interval,
    check_ranges,
    derive_euler_pack_max_n,
    derive_packed_key_max_n,
    euler_pack_interval,
    packed_key_interval,
)
from repro.core.bfs import EULER_PACK_MAX_N, PACKED_KEY_MAX_N, packed_key_bound

I32 = jax.ShapeDtypeStruct((8,), jnp.int32)
F32 = jax.ShapeDtypeStruct((8,), jnp.float32)
SCALAR = jax.ShapeDtypeStruct((), jnp.int32)


# ---------------------------------------------------------------- intervals

def test_interval_arithmetic():
    a = Interval.of(0, 10)
    b = Interval.of(-3, 4)
    assert (a + b).lo == -3 and (a + b).hi == 14
    assert (a - b).lo == -4 and (a - b).hi == 13
    assert (a * b).lo == -30 and (a * b).hi == 40
    assert a.neg().lo == -10 and a.neg().hi == 0
    assert a.min_(b).hi == 4 and a.max_(b).lo == 0


def test_interval_sentinel_semantics():
    depth = Interval.of(0, 63, sentinel=INT32_MAX)
    assert depth.taints_float()
    assert not depth.fits(jnp.int16)       # sentinel is part of the hull
    assert depth.fits(jnp.int32)
    stripped = Interval(depth.lo, depth.hi)
    assert not stripped.taints_float()
    assert stripped.fits(jnp.int16)


def test_interval_top_never_flags():
    top = Interval.top()
    assert top.fits(jnp.int8)
    assert (top + Interval.of(0, 5)).unknown
    assert not top.taints_float()


def test_union_keeps_single_sentinel_folds_two():
    a = Interval.of(0, 3, sentinel=INT32_MAX)
    b = Interval.of(5, 9)
    u = a.union(b)
    assert u.sentinel == INT32_MAX and u.lo == 0 and u.hi == 9
    c = Interval.of(0, 3, sentinel=7)
    d = Interval.of(0, 3, sentinel=11)
    folded = c.union(d)
    assert folded.sentinel is None and folded.hi == 11


# ------------------------------------------------------- derived constants

def test_packed_key_bound_matches_interval_model():
    for n in (1, 2, 64, 46339):
        assert packed_key_interval(n).hi == packed_key_bound(n)


def test_derived_packed_key_max_n_equals_runtime_constant():
    assert derive_packed_key_max_n() == PACKED_KEY_MAX_N
    assert packed_key_bound(PACKED_KEY_MAX_N) <= INT32_MAX
    assert packed_key_bound(PACKED_KEY_MAX_N + 1) > INT32_MAX


def test_derived_euler_pack_max_n_equals_runtime_constant():
    assert derive_euler_pack_max_n() == EULER_PACK_MAX_N
    assert euler_pack_interval(EULER_PACK_MAX_N).fits(jnp.uint32)


# -------------------------------------------------- synthetic jaxpr checks

def test_flags_exactly_the_overflowing_op():
    """dist·(n+1) fits int32 one past the switch; the +id does not —
    the finding must localize to the add, not the mul."""
    n = PACKED_KEY_MAX_N + 1

    def pack(dist, ids, base):
        return dist * base + ids

    findings = check_ranges(
        pack,
        [Interval.of(0, n), Interval.of(0, n), Interval.const(n + 1)],
        I32, I32, SCALAR)
    assert len(findings) == 1
    f = findings[0]
    assert f.kind == "int-overflow" and f.primitive == "add"


def test_clean_at_the_switch_point():
    n = PACKED_KEY_MAX_N

    def pack(dist, ids, base):
        return dist * base + ids

    assert check_ranges(
        pack,
        [Interval.of(0, n), Interval.of(0, n), Interval.const(n + 1)],
        I32, I32, SCALAR) == []


def test_mul_overflow_flags_the_mul():
    def f(x, y):
        return x * y

    findings = check_ranges(
        f, [Interval.of(0, 2 ** 16), Interval.of(0, 2 ** 16)], I32, I32)
    assert [x.primitive for x in findings] == ["mul"]


def test_cast_overflow():
    def f(x):
        return x.astype(jnp.int16)

    findings = check_ranges(f, [Interval.of(0, 100_000)], I32)
    assert [x.kind for x in findings] == ["cast-overflow"]
    assert check_ranges(f, [Interval.of(0, 1000)], I32) == []


def test_unknown_seed_never_flags():
    def f(x, y):
        return (x * y + x).astype(jnp.int8)

    assert check_ranges(f, [Interval.top(), Interval.top()], I32, I32) == []


def test_reduce_sum_overflow():
    def f(x):
        # dtype pinned so the x64 CI leg doesn't widen the accumulator
        return jnp.sum(x, dtype=jnp.int32)

    big = Interval.of(0, INT32_MAX // 2)
    findings = check_ranges(f, [big], I32)
    assert any(x.kind == "int-overflow" for x in findings)
    assert check_ranges(f, [Interval.of(0, 3)], I32) == []


# ----------------------------------------------------- the PR 5 regression

def test_pr5_unclamped_inf_depth_flags():
    """The shipped bug: unreachable-depth sentinel multiplied into the
    effective weight without a guard — poisoning every downstream sort
    with INF. Statically: sentinel-escape at the float cast."""

    def buggy_eff(depth, w):
        return depth.astype(jnp.float32) * w

    findings = check_ranges(
        buggy_eff, [Interval.of(0, 63, sentinel=INT32_MAX),
                    Interval.of(0, 1)], I32, F32)
    assert [x.kind for x in findings] == ["sentinel-escape"]


def test_pr5_guarded_depth_is_clean():
    """The fix idiom (`bfs.finite_depth`): jnp.where(d == INF, 0, d)
    strips the sentinel — select refinement must prove the cast safe."""

    def clean_eff(depth, w):
        safe = jnp.where(depth == INT32_MAX, 0, depth)
        return safe.astype(jnp.float32) * w

    assert check_ranges(
        clean_eff, [Interval.of(0, 63, sentinel=INT32_MAX),
                    Interval.of(0, 1)], I32, F32) == []


def test_effective_weights_witness_is_clean():
    """The real `core.bfs.effective_weights` guard, traced end-to-end
    with sentinel-bearing depth seeds."""
    from repro.core.bfs import effective_weights

    L, n = 8, 64
    findings = check_ranges(
        effective_weights,
        [Interval.of(0, n - 1), Interval.of(0, n - 1), Interval.of(0, 1),
         Interval.of(0, n - 1, sentinel=INT32_MAX)],
        jax.ShapeDtypeStruct((L,), jnp.int32),
        jax.ShapeDtypeStruct((L,), jnp.int32),
        jax.ShapeDtypeStruct((L,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        static_kwargs=dict(n=n))
    assert findings == []
