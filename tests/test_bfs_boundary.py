"""The packed-key switch boundary (`bfs.PACKED_KEY_MAX_N`): at the
largest int32-safe n the doubling engine runs its packed single-scatter
relaxation; one node more and it falls back to the unpacked two-scatter
pass. Both sides of the switch must be bit-identical to the
level-synchronous engine — the graphs are sparse chains anchored at the
TOP of the id range so the packed keys actually reach their maxima."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bfs import (
    EULER_PACK_MAX_N,
    PACKED_KEY_MAX_N,
    bfs_doubling,
    bfs_levels,
    packed_key_bound,
)

INT32_MAX = np.int32(np.iinfo(np.int32).max)


def _top_chain_graph(n, length=9):
    """A chain over the `length` highest node ids (maximizing dist·
    (n+1)+id keys) plus one far spur; everything else unreachable."""
    hi = np.arange(n - length, n, dtype=np.int32)
    u = hi[:-1]
    v = hi[1:]
    # a spur from the chain's far end to node 0: max-id → min-id edge
    u = np.append(u, hi[0])
    v = np.append(v, np.int32(0))
    return jnp.asarray(u), jnp.asarray(v)


def test_constants_bracket_int32():
    assert packed_key_bound(PACKED_KEY_MAX_N) <= np.iinfo(np.int32).max
    assert packed_key_bound(PACKED_KEY_MAX_N + 1) > np.iinfo(np.int32).max
    assert PACKED_KEY_MAX_N == 46339
    assert EULER_PACK_MAX_N == 0xFFFF


@pytest.mark.parametrize("n", [PACKED_KEY_MAX_N, PACKED_KEY_MAX_N + 1])
def test_engines_bit_identical_across_switch(n):
    u, v = _top_chain_graph(n)
    root = jnp.int32(n - 1)
    dd, pd = bfs_doubling(u, v, n, root)
    dl, pl = bfs_levels(u, v, n, root)
    dd, pd = np.asarray(dd), np.asarray(pd)
    dl, pl = np.asarray(dl), np.asarray(pl)
    assert np.array_equal(dd, dl)
    assert np.array_equal(pd, pl)
    # sanity on the expected structure, not just mutual agreement
    assert dd[n - 1] == 0 and pd[n - 1] == -1
    assert dd[n - 9] == 8 and dd[0] == 9
    unreachable = np.ones(n, bool)
    unreachable[n - 9:] = False
    unreachable[0] = False
    assert np.all(dd[unreachable] == INT32_MAX)
    assert np.all(pd[unreachable] == -1)


@pytest.mark.parametrize("n", [PACKED_KEY_MAX_N, PACKED_KEY_MAX_N + 1])
def test_edge_mask_respected_across_switch(n):
    u, v = _top_chain_graph(n)
    # mask off the spur: node 0 must become unreachable on both engines
    mask = jnp.asarray(np.arange(len(u)) != len(u) - 1)
    root = jnp.int32(n - 1)
    dd, pd = bfs_doubling(u, v, n, root, edge_mask=mask)
    dl, pl = bfs_levels(u, v, n, root, edge_mask=mask)
    assert np.array_equal(np.asarray(dd), np.asarray(dl))
    assert np.array_equal(np.asarray(pd), np.asarray(pl))
    assert np.asarray(dd)[0] == INT32_MAX
