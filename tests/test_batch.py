"""Batched multi-graph sparsification: `GraphBatch` + vmapped phase 1.

The contract under test: every graph in a padded batch yields an
`edge_mask` BIT-IDENTICAL to (a) its single-graph `lgrass_sparsify` run
and (b) the `baseline_sparsify` oracle — padding must be invisible.
Covers three graph families (random lognormal, random ties, power-grid),
mixed sizes in one batch, both marking schedules, per-graph budgets, the
k_cap overflow/dirty recovery path, and the serving bucketing layer.
"""
import numpy as np
import pytest

from _prop import cases, integers
from repro.core import (baseline_sparsify, lgrass_sparsify,
                        lgrass_sparsify_batch)
from repro.core.graph import (GraphBatch, PAD_ENDPOINT, PAD_WEIGHT,
                              powergrid_like_graph, random_connected_graph)
from repro.serve.sparsify_service import SparsifyService, next_pow2


def _mixed_families():
    """Mixed sizes across >= 3 families, deliberately not sorted by size."""
    return [
        random_connected_graph(30, 60, seed=0, weight="lognormal"),
        random_connected_graph(45, 110, seed=1, weight="ties"),
        powergrid_like_graph(6, 0.4, seed=3),
        random_connected_graph(24, 40, seed=2, weight="lognormal"),
        powergrid_like_graph(8, 0.3, seed=4),
        random_connected_graph(40, 95, seed=5, weight="ties"),
    ]


def test_graphbatch_padding_layout():
    graphs = _mixed_families()
    batch = GraphBatch.from_graphs(graphs)
    assert batch.batch_size == len(graphs)
    assert batch.n_max == max(g.n for g in graphs)
    assert batch.L_max == max(g.m for g in graphs)
    for i, g in enumerate(graphs):
        assert np.array_equal(batch.u[i, : g.m], g.u)
        assert np.array_equal(batch.v[i, : g.m], g.v)
        assert np.array_equal(batch.w[i, : g.m], g.w)
        assert batch.edge_valid[i, : g.m].all()
        # padding slots: sentinel self loops, masked out
        assert not batch.edge_valid[i, g.m:].any()
        assert (batch.u[i, g.m:] == PAD_ENDPOINT).all()
        assert (batch.v[i, g.m:] == PAD_ENDPOINT).all()
        assert (batch.w[i, g.m:] == PAD_WEIGHT).all()


def test_graphbatch_rejects_too_small_bucket():
    g = random_connected_graph(20, 30, seed=0)
    with pytest.raises(ValueError):
        GraphBatch.from_graphs([g], n_max=8)
    with pytest.raises(ValueError):
        GraphBatch.from_graphs([g], L_max=10)
    with pytest.raises(ValueError):
        GraphBatch.from_graphs([])


@pytest.mark.parametrize("parallel", [True, False])
def test_batch_bit_identical_to_single_and_baseline(parallel):
    graphs = _mixed_families()
    results = lgrass_sparsify_batch(graphs, budget=8, parallel=parallel)
    for i, (g, r) in enumerate(zip(graphs, results)):
        single = lgrass_sparsify(g, budget=8, parallel=parallel)
        base = baseline_sparsify(g, budget=8)
        assert np.array_equal(r.edge_mask, single.edge_mask), i
        assert np.array_equal(r.tree_mask, single.tree_mask), i
        assert np.array_equal(r.accepted_mask, single.accepted_mask), i
        assert np.array_equal(r.edge_mask, base.edge_mask), i
        assert r.n_accepted == single.n_accepted
        assert r.n_groups == single.n_groups
        assert r.n_overflow_groups == single.n_overflow_groups
        assert r.n_dirty == single.n_dirty


def test_batch_per_graph_default_budgets():
    graphs = _mixed_families()
    results = lgrass_sparsify_batch(graphs)  # budget=None -> per-graph
    for g, r in zip(graphs, results):
        single = lgrass_sparsify(g)
        assert np.array_equal(r.edge_mask, single.edge_mask)


def test_batch_budget_sequence():
    graphs = _mixed_families()
    budgets = [2, 4, 6, 8, 3, 5]
    results = lgrass_sparsify_batch(graphs, budget=budgets)
    for g, b, r in zip(graphs, budgets, results):
        assert r.n_accepted <= b
        single = lgrass_sparsify(g, budget=b)
        assert np.array_equal(r.edge_mask, single.edge_mask)
    with pytest.raises(ValueError):
        lgrass_sparsify_batch(graphs, budget=[1, 2])


def test_batch_overflow_recovery_dirty_path():
    """k_cap=1 overflows nearly every group; the recovery tail must still
    reproduce the oracle bit-exactly, through the batched path."""
    dense = random_connected_graph(40, 110, seed=9)
    graphs = [dense, powergrid_like_graph(6, 0.4, seed=3)]
    results = lgrass_sparsify_batch(graphs, budget=20, k_cap=1)
    assert results[0].n_overflow_groups > 0
    assert results[0].n_dirty > 0
    for g, r in zip(graphs, results):
        base = baseline_sparsify(g, budget=20)
        assert np.array_equal(r.edge_mask, base.edge_mask)
        single = lgrass_sparsify(g, budget=20, k_cap=1)
        assert r.n_overflow_groups == single.n_overflow_groups
        assert r.n_dirty == single.n_dirty


@pytest.mark.parametrize("seed", cases(integers(0, 100_000), n_cases=6,
                                       seed=123))
def test_batch_property_sweep(seed):
    """Random batch compositions stay bit-identical to single-graph runs."""
    rng = np.random.default_rng(seed)
    graphs = [
        random_connected_graph(
            int(rng.integers(16, 48)),
            int(rng.integers(20, 90)),
            seed=int(rng.integers(0, 2**31)),
            weight=["lognormal", "ties"][int(rng.integers(2))],
        )
        for _ in range(int(rng.integers(2, 5)))
    ]
    for r, g in zip(lgrass_sparsify_batch(graphs, budget=6), graphs):
        assert np.array_equal(
            r.edge_mask, lgrass_sparsify(g, budget=6).edge_mask
        )


def test_next_pow2():
    assert [next_pow2(x) for x in (1, 2, 3, 4, 5, 63, 64, 65)] == [
        1, 2, 4, 4, 8, 64, 64, 128]


def test_sparsify_service_buckets_and_order():
    graphs = _mixed_families()
    svc = SparsifyService(min_n_bucket=16, min_L_bucket=32, parallel=False)
    results = svc.sparsify(graphs, budget=8)
    # request order preserved, results exact
    for g, r in zip(graphs, results):
        single = lgrass_sparsify(g, budget=8, parallel=False)
        assert np.array_equal(r.edge_mask, single.edge_mask)
    # bucketing bounds the number of dispatched shapes
    assert svc.stats.n_graphs == len(graphs)
    assert svc.stats.n_dispatches == len(svc.stats.bucket_counts)
    assert svc.stats.n_dispatches < len(graphs)
    assert 0.0 <= svc.stats.padding_overhead < 1.0
    # keys are pow2 buckets that fit their graphs
    for (nb, lb), cnt in svc.stats.bucket_counts.items():
        assert nb == next_pow2(nb) and lb == next_pow2(lb)


def test_sparsify_service_chunks_large_batches():
    graphs = [random_connected_graph(20, 30, seed=s) for s in range(5)]
    svc = SparsifyService(max_batch_size=2, parallel=False)
    results = svc.sparsify(graphs, budget=4)
    assert svc.stats.n_dispatches == 3  # 5 graphs, one bucket, chunks of 2
    for g, r in zip(graphs, results):
        assert np.array_equal(
            r.edge_mask,
            lgrass_sparsify(g, budget=4, parallel=False).edge_mask,
        )


def test_sparsify_service_ndarray_budget():
    graphs = [random_connected_graph(20, 30, seed=s) for s in range(3)]
    svc = SparsifyService(parallel=False)
    results = svc.sparsify(graphs, budget=np.array([2, 3, 4]))
    for g, b, r in zip(graphs, (2, 3, 4), results):
        assert np.array_equal(
            r.edge_mask,
            lgrass_sparsify(g, budget=b, parallel=False).edge_mask,
        )
    # numpy scalar broadcasts like a python int
    r0 = svc.sparsify(graphs[:1], budget=np.int64(4))[0]
    assert np.array_equal(
        r0.edge_mask,
        lgrass_sparsify(graphs[0], budget=4, parallel=False).edge_mask,
    )


def test_sparsify_service_pads_batch_axis():
    """Odd chunk sizes are padded to pow2 with placeholder rows that must
    not leak into the results (and keep compiled shapes shared)."""
    graphs = [random_connected_graph(20, 30, seed=s) for s in range(3)]
    svc = SparsifyService(parallel=False)
    results = svc.sparsify(graphs, budget=4)   # one chunk of 3 -> B=4
    assert len(results) == len(graphs)
    assert svc.stats.n_dispatches == 1
    _, L_bucket = svc.bucket_key(graphs[0])
    assert svc.stats.n_padded_edge_slots == 4 * L_bucket  # B padded to 4
    for g, r in zip(graphs, results):
        assert np.array_equal(
            r.edge_mask,
            lgrass_sparsify(g, budget=4, parallel=False).edge_mask,
        )


def test_sparsify_service_warmup_precompiles():
    """warmup() compiles the bucket program off the request path; the
    request then reuses it (no new jit cache entry) and results stay
    exact. Warmup never touches the request-path stats."""
    from repro.core.sparsify import lgrass_device_batched

    svc = SparsifyService(parallel=False)
    size_before = lgrass_device_batched._cache_size()
    n_disp = svc.warmup([(20, 30), (22, 31)])  # same pow2 bucket
    assert n_disp == 1
    assert svc.stats.n_warmup_dispatches == 1
    assert svc.stats.warmup_seconds > 0.0
    assert svc.stats.n_graphs == 0 and svc.stats.n_dispatches == 0
    size_warm = lgrass_device_batched._cache_size()
    assert size_warm == size_before + 1

    g = random_connected_graph(20, 30, seed=3)
    [r] = svc.sparsify([g])
    assert lgrass_device_batched._cache_size() == size_warm  # cache hit
    assert np.array_equal(
        r.edge_mask, lgrass_sparsify(g, parallel=False).edge_mask
    )


def test_sparsify_service_host_recovery_mode():
    """The oracle tail stays available behind recovery='host'."""
    graphs = [random_connected_graph(20, 30, seed=s) for s in range(2)]
    svc = SparsifyService(parallel=False, recovery="host")
    for g, r in zip(graphs, svc.sparsify(graphs, budget=4)):
        assert np.array_equal(
            r.edge_mask,
            lgrass_sparsify(g, budget=4, parallel=False,
                            recovery="host").edge_mask,
        )


def test_sparsify_service_mixed_budgets():
    graphs = _mixed_families()[:3]
    svc = SparsifyService(parallel=False)
    results = svc.sparsify(graphs, budget=[None, 5, None])
    assert np.array_equal(
        results[0].edge_mask, lgrass_sparsify(graphs[0]).edge_mask
    )
    assert np.array_equal(
        results[1].edge_mask,
        lgrass_sparsify(graphs[1], budget=5).edge_mask,
    )
    assert np.array_equal(
        results[2].edge_mask, lgrass_sparsify(graphs[2]).edge_mask
    )
