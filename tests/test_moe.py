"""MoE dispatch: capacity discipline + equivalence with a dense
loop-over-experts reference when nothing is dropped."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.layers import ParamSet
from repro.models.moe import init_moe, moe_ffn


def _setup(e=4, k=2, d=16, f=32, cf=8.0):
    cfg = dataclasses.replace(
        ARCHS["dbrx-132b"].reduced(), n_experts=e, moe_top_k=k,
        d_model=d, d_ff=f, capacity_factor=cf)
    ps = ParamSet()
    init_moe(ps, jax.random.PRNGKey(0), cfg)
    return cfg, ps.values


def _dense_ref(params, cfg, x):
    """Loop over experts densely; weight by normalised top-k gates."""
    dt = x.dtype
    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(dt))
    gates = jax.nn.softmax(logits.astype(jnp.float32), -1)
    vals, idx = jax.lax.top_k(gates, cfg.moe_top_k)
    vals = vals / vals.sum(-1, keepdims=True)
    y = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        h = jnp.einsum("bsd,df->bsf", x, params["wi"][e].astype(dt))
        if cfg.act == "swiglu":
            g = jnp.einsum("bsd,df->bsf", x, params["wg"][e].astype(dt))
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h)
        o = jnp.einsum("bsf,fd->bsd", h, params["wo"][e].astype(dt))
        wsel = jnp.where(idx == e, vals, 0.0).sum(-1)
        y = y + o * wsel[..., None].astype(dt)
    return y


def test_moe_matches_dense_reference_no_drop():
    cfg, params = _setup(cf=8.0)  # capacity huge -> nothing dropped
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 12, 16)),
                    jnp.float32)
    y, aux = moe_ffn(params, cfg, x)
    want = _dense_ref(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-5, rtol=1e-4)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_tokens():
    cfg, params = _setup(e=2, k=1, cf=0.26)  # tiny capacity
    x = jnp.asarray(np.random.default_rng(1).standard_normal((1, 16, 16)),
                    jnp.float32)
    y, _ = moe_ffn(params, cfg, x)
    # some rows must be exactly zero (dropped -> no expert contribution)
    norms = np.linalg.norm(np.asarray(y)[0], axis=-1)
    assert (norms == 0.0).any()
    assert (norms > 0.0).any()


def test_moe_padded_experts_never_routed():
    cfg, params = _setup(e=4, k=2)
    cfg = dataclasses.replace(cfg, real_n_experts=2)  # 2 padded experts
    x = jnp.asarray(np.random.default_rng(2).standard_normal((1, 8, 16)),
                    jnp.float32)
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    gates = jax.nn.softmax(
        jnp.where(jnp.arange(4) >= 2, -1e9, logits.astype(jnp.float32)), -1)
    _, idx = jax.lax.top_k(gates, 2)
    assert int(jnp.max(idx)) < 2
    y, _ = moe_ffn(params, cfg, x)  # must not blow up
    assert np.all(np.isfinite(np.asarray(y)))


def test_moe_grads_flow_to_router():
    cfg, params = _setup()
    x = jnp.asarray(np.random.default_rng(3).standard_normal((1, 8, 16)),
                    jnp.float32)

    def loss(p):
        y, aux = moe_ffn(p, cfg, x)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).sum()) > 0.0
    assert float(jnp.abs(g["wi"]).sum()) > 0.0
