"""End-to-end behaviour tests for the LGRASS system."""
import numpy as np
import pytest

from repro.core import (Graph, baseline_sparsify, default_budget,
                        lgrass_sparsify, official_case,
                        random_connected_graph)


def test_official_case_shapes():
    g = official_case("case1")
    assert 3900 <= g.n <= 4200          # ~4K nodes as in the IPCC task
    g.validate()


def test_end_to_end_case1_reduced():
    """Full pipeline on a (scaled-down) official-style case: the linear
    LGRASS output equals the baseline's on a power-grid topology."""
    from repro.core.graph import powergrid_like_graph
    g = powergrid_like_graph(12, 0.25, seed=42)   # 144 nodes
    b = baseline_sparsify(g)
    r = lgrass_sparsify(g)
    assert np.array_equal(b.edge_mask, r.edge_mask)
    kept = r.edge_mask.sum() / g.m
    assert 0.3 < kept < 1.0  # it actually sparsifies


def test_larger_graph_runs_and_is_consistent():
    g = random_connected_graph(400, 1200, seed=21)
    r1 = lgrass_sparsify(g, budget=30, parallel=True)
    r2 = lgrass_sparsify(g, budget=30, parallel=False)
    assert np.array_equal(r1.edge_mask, r2.edge_mask)
    assert r1.n_accepted <= 30


def test_default_budget():
    assert default_budget(1000) == 50
    assert default_budget(10) == 1
