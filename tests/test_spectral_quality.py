"""Direct spectral quality of the sparsifier — dense ground truth.

Everything else in the suite asserts *self-consistency*: device paths
against host oracles against the baseline greedy. None of it would
notice if the whole family of implementations drifted to a spectrally
worse algorithm in lockstep. This tier pins the output against the
O(n^3) dense formulation (`core.resistance` numpy helpers, float64
pseudoinverse) on small graphs:

  * the device RES stage (root-path sums + LCA) must reproduce the
    textbook effective resistance of the spanning tree;
  * the sparsifier's Laplacian must preserve quadratic forms at least
    as well as the baseline greedy's (they are bit-identical today, so
    the bound is tight — a refactor that degrades quality while keeping
    its own oracles self-consistent trips these);
  * Rayleigh-monotonicity sanity: subgraphs only increase effective
    resistance, added edges only improve the preservation ratio.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _prop import cases, integers, sampled_from
from repro.core import baseline_sparsify, lgrass_sparsify
from repro.core.graph import (feeder_like_graph, powergrid_like_graph,
                              random_connected_graph)
from repro.core.resistance import (dense_effective_resistance_np,
                                   dense_laplacian_np, spectral_bounds_np)
from repro.core.sparsify import phase1_device


def _dense_er(g, mask, qu, qv):
    L = dense_laplacian_np(g.n, g.u, g.v, g.w, mask=mask)
    return dense_effective_resistance_np(L, qu, qv)


@pytest.mark.parametrize(
    "seed,weight",
    cases(integers(0, 100_000), sampled_from(["lognormal", "uniform"]),
          n_cases=6, seed=53),
)
def test_tree_resistance_matches_dense_pinv(seed, weight):
    """The linear-time tree effective resistance (root-path sums + LCA,
    float32 on device) equals the dense pseudoinverse ER of the spanning
    tree to float32 accuracy — ties the RES stage to ground truth."""
    g = random_connected_graph(24, 50, seed=seed, weight=weight)
    d = {k: np.asarray(v) for k, v in phase1_device(
        jnp.asarray(g.u, jnp.int32), jnp.asarray(g.v, jnp.int32),
        jnp.asarray(g.w, jnp.float32), g.n).items()}
    tree = d["tree_mask"].astype(bool)
    offtree = ~tree
    # device criticality = w * R_T(u, v) on off-tree edges
    r_dev = d["crit"][offtree] / g.w[offtree]
    r_dense = _dense_er(g, tree, g.u[offtree], g.v[offtree])
    np.testing.assert_allclose(r_dev, r_dense, rtol=2e-4, atol=1e-5)


def _quality(g, mask):
    """(lam_min, lam_max) of the pencil sparsifier-vs-full Laplacian."""
    L_full = dense_laplacian_np(g.n, g.u, g.v, g.w)
    L_sub = dense_laplacian_np(g.n, g.u, g.v, g.w, mask=mask)
    return spectral_bounds_np(L_full, L_sub)


@pytest.mark.parametrize(
    "seed,budget",
    cases(integers(0, 100_000), sampled_from([4, 8, 14]),
          n_cases=6, seed=59),
)
def test_sparsifier_quality_bounded_by_baseline(seed, budget):
    g = random_connected_graph(30, 70, seed=seed)
    base = baseline_sparsify(g, budget=budget)
    dev = lgrass_sparsify(g, budget=budget)
    lo_b, hi_b = _quality(g, base.edge_mask)
    lo_d, hi_d = _quality(g, dev.edge_mask)
    # subgraph sparsifier: the pencil lives in [0, 1]
    assert -1e-9 <= lo_d and hi_d <= 1.0 + 1e-9
    # connectivity preserved: the sparsifier never collapses a direction
    assert lo_d > 1e-6
    # LGRASS must be at least as good as the baseline greedy (bit-equal
    # today; the tolerance leaves room only for eigensolver noise)
    assert lo_d >= lo_b - 1e-9
    assert hi_d <= hi_b + 1e-9


@pytest.mark.parametrize("family", ["powergrid", "feeder"])
def test_sparsifier_improves_on_bare_tree(family):
    """Adding the accepted off-tree edges must improve (or preserve) the
    quadratic-form lower bound vs the spanning tree alone — the whole
    point of spending the budget."""
    if family == "powergrid":
        g, budget = powergrid_like_graph(5, 0.5, seed=7), 4
    else:
        g, budget = feeder_like_graph(48, 24, span=5, seed=7), 4
    dev = lgrass_sparsify(g, budget=budget)
    assert dev.n_accepted > 0  # budget actually spent on this input
    lo_tree, _ = _quality(g, dev.tree_mask)
    lo_sp, _ = _quality(g, dev.edge_mask)
    assert lo_sp >= lo_tree - 1e-12


def test_effective_resistance_rayleigh_monotone():
    """R is monotone under edge removal (Rayleigh): ER in the sparsifier
    >= ER in the full graph, and ER in the tree >= ER in the sparsifier,
    for every off-tree edge's endpoint pair."""
    g = random_connected_graph(26, 60, seed=3)
    dev = lgrass_sparsify(g, budget=6)
    off = ~dev.tree_mask
    qu, qv = g.u[off], g.v[off]
    r_full = _dense_er(g, np.ones(g.m, bool), qu, qv)
    r_sp = _dense_er(g, dev.edge_mask, qu, qv)
    r_tree = _dense_er(g, dev.tree_mask, qu, qv)
    assert (r_sp >= r_full - 1e-9).all()
    assert (r_tree >= r_sp - 1e-9).all()


def test_quality_identical_across_schedules():
    """Every engine configuration is bit-identical, so its spectral
    quality must be exactly equal — a cheap guard that an
    engine-specific bug cannot pass the parity tier by breaking both
    sides equally. The matrix covers the marking schedule, both BFS
    engines, the Euler-tour vs lifting LCA, and the batched dispatch
    (each graph's lane vs its own single-graph run)."""
    from repro.core import lgrass_sparsify_batch

    gs = [random_connected_graph(30, 70, seed=11),
          feeder_like_graph(32, 16, span=6, seed=11)]
    for g in gs:
        ref = lgrass_sparsify(g, budget=8, schedule="scan",
                              parallel=False).edge_mask
        q_ref = _quality(g, ref)
        for bfs_engine in ("doubling", "levels"):
            for use_euler_lca in (True, False):
                m = lgrass_sparsify(
                    g, budget=8, schedule="chunked", p1_chunk=4,
                    bfs_engine=bfs_engine,
                    use_euler_lca=use_euler_lca).edge_mask
                cfg = (bfs_engine, use_euler_lca)
                assert np.array_equal(ref, m), cfg
                assert q_ref == _quality(g, m), cfg
    # batched: one vmapped dispatch, every lane == its single-graph run
    for bfs_engine in ("doubling", "levels"):
        for use_euler_lca in (True, False):
            batched = lgrass_sparsify_batch(
                gs, budget=8, bfs_engine=bfs_engine,
                use_euler_lca=use_euler_lca)
            for g, res in zip(gs, batched):
                single = lgrass_sparsify(
                    g, budget=8, bfs_engine=bfs_engine,
                    use_euler_lca=use_euler_lca)
                cfg = (bfs_engine, use_euler_lca)
                assert np.array_equal(res.edge_mask,
                                      single.edge_mask), cfg
                assert _quality(g, res.edge_mask) == _quality(
                    g, single.edge_mask), cfg
