"""Per-kernel Pallas tests: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles in ref.py (interpret=True executes the kernel body on
CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk_qkv(rng, b, s, h, kv, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    return q, k, v


def _ref_out(q, k, v, causal, window):
    b, s, h, d = q.shape
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kb = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vb = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    pos = jnp.arange(s, dtype=jnp.int32)
    o = ref.flash_attention_ref(qb, kb, vb, pos, pos, causal=causal,
                                window=window)
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("s,d,h,kv", [
    (256, 64, 4, 4),
    (256, 128, 4, 2),   # GQA
    (512, 64, 2, 1),    # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(s, d, h, kv, causal):
    rng = np.random.default_rng(s + d)
    q, k, v = _mk_qkv(rng, 2, s, h, kv, d, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = _ref_out(q, k, v, causal, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_window():
    rng = np.random.default_rng(7)
    q, k, v = _mk_qkv(rng, 1, 384, 2, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=100,
                              interpret=True)
    want = _ref_out(q, k, v, True, 100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(8)
    q, k, v = _mk_qkv(rng, 1, 256, 2, 2, 64, jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = _ref_out(q.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32), True, None)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=3e-2, rtol=3e-2)


def test_flash_attention_block_sweep():
    rng = np.random.default_rng(9)
    q, k, v = _mk_qkv(rng, 1, 512, 2, 2, 64, jnp.float32)
    want = _ref_out(q, k, v, True, None)
    for bq, bk in [(128, 128), (256, 128), (128, 256), (64, 64)]:
        out = ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5,
                                   err_msg=f"blocks {bq}x{bk}")


@pytest.mark.parametrize("n", [512, 1024, 4096, 5000])
def test_radix_hist_kernel(n):
    rng = np.random.default_rng(n)
    d = jnp.asarray(rng.integers(0, 256, n), jnp.int32)
    rank, hist = ops.bucket_rank_hist(d, interpret=True)
    rr, hr = ref.bucket_rank_hist_ref(d)
    assert np.array_equal(np.asarray(rank), np.asarray(rr))
    assert np.array_equal(np.asarray(hist), np.asarray(hr))


def test_radix_argsort_kernel_matches_core():
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.integers(0, 2 ** 32, 3000, dtype=np.uint32))
    perm = ops.radix_argsort_u32(keys, interpret=True)
    srt = np.asarray(keys)[np.asarray(perm)]
    assert np.array_equal(srt, np.sort(np.asarray(keys)))


@pytest.mark.parametrize("l,w", [(100, 1), (1024, 2), (2000, 4)])
def test_bitmap_intersect(l, w):
    rng = np.random.default_rng(l + w)
    m1 = jnp.asarray(rng.integers(0, 2 ** 32, (l, w), dtype=np.uint32))
    m2 = jnp.asarray((rng.integers(0, 2 ** 32, (l, w), dtype=np.uint32)
                      * (rng.random((l, w)) < 0.2)).astype(np.uint32))
    out = ops.bitmap_intersect_any(m1, m2, interpret=True)
    want = ref.bitmap_intersect_any_ref(m1, m2)
    assert np.array_equal(np.asarray(out), np.asarray(want))
