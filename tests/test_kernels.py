"""Per-kernel Pallas tests: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles in ref.py (interpret=True executes the kernel body on
CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk_qkv(rng, b, s, h, kv, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    return q, k, v


def _ref_out(q, k, v, causal, window):
    b, s, h, d = q.shape
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kb = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vb = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    pos = jnp.arange(s, dtype=jnp.int32)
    o = ref.flash_attention_ref(qb, kb, vb, pos, pos, causal=causal,
                                window=window)
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("s,d,h,kv", [
    (256, 64, 4, 4),
    (256, 128, 4, 2),   # GQA
    (512, 64, 2, 1),    # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(s, d, h, kv, causal):
    rng = np.random.default_rng(s + d)
    q, k, v = _mk_qkv(rng, 2, s, h, kv, d, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = _ref_out(q, k, v, causal, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_window():
    rng = np.random.default_rng(7)
    q, k, v = _mk_qkv(rng, 1, 384, 2, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=100,
                              interpret=True)
    want = _ref_out(q, k, v, True, 100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(8)
    q, k, v = _mk_qkv(rng, 1, 256, 2, 2, 64, jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = _ref_out(q.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32), True, None)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=3e-2, rtol=3e-2)


def test_flash_attention_block_sweep():
    rng = np.random.default_rng(9)
    q, k, v = _mk_qkv(rng, 1, 512, 2, 2, 64, jnp.float32)
    want = _ref_out(q, k, v, True, None)
    for bq, bk in [(128, 128), (256, 128), (128, 256), (64, 64)]:
        out = ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5,
                                   err_msg=f"blocks {bq}x{bk}")


@pytest.mark.parametrize("n", [512, 1024, 4096, 5000])
def test_radix_hist_kernel(n):
    rng = np.random.default_rng(n)
    d = jnp.asarray(rng.integers(0, 256, n), jnp.int32)
    rank, hist = ops.bucket_rank_hist(d, interpret=True)
    rr, hr = ref.bucket_rank_hist_ref(d)
    assert np.array_equal(np.asarray(rank), np.asarray(rr))
    assert np.array_equal(np.asarray(hist), np.asarray(hr))


def test_radix_argsort_kernel_matches_core():
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.integers(0, 2 ** 32, 3000, dtype=np.uint32))
    perm = ops.radix_argsort_u32(keys, interpret=True)
    srt = np.asarray(keys)[np.asarray(perm)]
    assert np.array_equal(srt, np.sort(np.asarray(keys)))


def _random_lifting(n, extra, seed):
    from repro.core import _host as H
    from repro.core.graph import random_connected_graph

    g = random_connected_graph(n, extra, seed=seed)
    u64, v64 = g.u.astype(np.int64), g.v.astype(np.int64)
    root = H.select_root_np(u64, v64, g.n)
    depth, parent = H.bfs_np(u64, v64, g.n, root)
    up = H.build_lifting_np(parent, depth, g.n)
    return up, depth


@pytest.mark.parametrize("n,m,block", [(40, 64, 64), (60, 300, 128),
                                       (100, 257, 128)])
def test_tree_dist_kernel(n, m, block):
    """Kernel == plain-gather ref == numpy host mirror, exactly (int ops)."""
    from repro.core import _host as H

    up, depth = _random_lifting(n, 2 * n, seed=n)
    rng = np.random.default_rng(m)
    a = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    b = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    upj, dj = jnp.asarray(up), jnp.asarray(depth)
    out = ops.tree_dist_pairs(upj, dj, a, b, block=block, interpret=True)
    want_ref = ref.tree_dist_pairs_ref(upj, dj, a, b)
    want_np = H.tree_dist_np(up, depth, np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(out), np.asarray(want_ref))
    assert np.array_equal(np.asarray(out), want_np)


def test_tree_dist_kernel_identical_and_adjacent():
    """Edge cases: d(x, x) = 0; d(child, parent) = 1."""
    up, depth = _random_lifting(30, 40, seed=5)
    nodes = jnp.arange(30, dtype=jnp.int32)
    upj, dj = jnp.asarray(up), jnp.asarray(depth)
    assert np.all(np.asarray(
        ops.tree_dist_pairs(upj, dj, nodes, nodes, interpret=True)) == 0)
    parents = jnp.asarray(up[0], jnp.int32)
    d = np.asarray(ops.tree_dist_pairs(upj, dj, nodes, parents,
                                       interpret=True))
    assert np.all(d == (np.asarray(depth) > 0).astype(int))


@pytest.mark.parametrize("l,w", [(100, 1), (1024, 2), (2000, 4)])
def test_bitmap_intersect(l, w):
    rng = np.random.default_rng(l + w)
    m1 = jnp.asarray(rng.integers(0, 2 ** 32, (l, w), dtype=np.uint32))
    m2 = jnp.asarray((rng.integers(0, 2 ** 32, (l, w), dtype=np.uint32)
                      * (rng.random((l, w)) < 0.2)).astype(np.uint32))
    out = ops.bitmap_intersect_any(m1, m2, interpret=True)
    want = ref.bitmap_intersect_any_ref(m1, m2)
    assert np.array_equal(np.asarray(out), np.asarray(want))


def _random_edges(n, m, seed):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m).astype(np.int32)
    v = ((u + 1 + rng.integers(0, n - 1, m)) % n).astype(np.int32)
    w = rng.lognormal(0.0, 1.0, m).astype(np.float32)
    return u, v, w


@pytest.mark.parametrize("n,m,p,block", [
    (40, 64, 8, 64),
    (64, 300, 16, 128),
    (100, 257, 4, 128),   # non-block-multiple edge count
    (128, 1000, 1, 512),
])
def test_spmv_kernel_matches_ref(n, m, p, block):
    """Laplacian spmv kernel == plain gather/scatter ref. float32 sums
    accumulate in different orders (one-hot matmul vs scatter-add), so
    allclose, not bit-equal — same contract as flash_attention."""
    u, v, w = _random_edges(n, m, seed=n + m)
    rng = np.random.default_rng(p)
    x = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    uj, vj, wj = jnp.asarray(u), jnp.asarray(v), jnp.asarray(w)
    out = ops.laplacian_spmv_edges(uj, vj, wj, x, block=block,
                                   interpret=True)
    want = ref.laplacian_spmv_ref(uj, vj, wj, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)
    # a Laplacian annihilates constants: L·1 = 0
    ones = jnp.ones((n, p), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.laplacian_spmv_edges(uj, vj, wj, ones,
                                            block=block, interpret=True)),
        0.0, atol=1e-4)


def test_spmv_kernel_degenerate_edges():
    """m == 0 returns zeros; zero-weight slots (the padding convention)
    contribute exactly nothing."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((16, 4)),
                    jnp.float32)
    z = jnp.zeros((0,), jnp.int32)
    out = ops.laplacian_spmv_edges(z, z, jnp.zeros((0,), jnp.float32), x,
                                   interpret=True)
    assert np.array_equal(np.asarray(out), np.zeros((16, 4), np.float32))
    u, v, w = _random_edges(16, 40, seed=3)
    keep = np.random.default_rng(4).random(40) < 0.5
    wz = np.where(keep, w, 0.0).astype(np.float32)
    out_masked = ops.laplacian_spmv_edges(
        jnp.asarray(u), jnp.asarray(v), jnp.asarray(wz), x,
        block=32, interpret=True)
    want = ref.laplacian_spmv_ref(jnp.asarray(u[keep]),
                                  jnp.asarray(v[keep]),
                                  jnp.asarray(w[keep]), x)
    np.testing.assert_allclose(np.asarray(out_masked), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_spmv_kernel_through_estimator():
    """The estimator's use_spmv_kernel path lands allclose to the
    default segment-sum path at the program level (same probes, same
    filter, different spmv engine)."""
    from repro.core.spectral_probe import probe_edge_resistance

    from repro.core.graph import random_connected_graph

    g = random_connected_graph(48, 96, seed=9)
    a = np.asarray(probe_edge_resistance(g.u, g.v, g.w, g.n,
                                         n_probes=32, n_iters=32, seed=1))
    b = np.asarray(probe_edge_resistance(g.u, g.v, g.w, g.n,
                                         n_probes=32, n_iters=32, seed=1,
                                         use_spmv_kernel=True))
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)
