"""BFS / MST / LCA / resistance: JAX implementations vs host oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _prop import cases, integers, sampled_from
from repro.core import _host as H
from repro.core.bfs import bfs, effective_weights, select_root
from repro.core.graph import random_connected_graph
from repro.core.lca import (build_lifting, lca, lca_with_shortcut, subroot,
                            tree_distance)
from repro.core.mst import boruvka_mst, kruskal_mst_numpy
from repro.core.resistance import (edge_resistance, node_parent_inv_w,
                                   root_path_sums)
from repro.core.sort import sort_f32_desc_stable


def _setup(n=60, m=120, seed=0, weight="lognormal"):
    g = random_connected_graph(n, m, seed=seed, weight=weight)
    u = jnp.asarray(g.u, jnp.int32)
    v = jnp.asarray(g.v, jnp.int32)
    w = jnp.asarray(g.w, jnp.float32)
    return g, u, v, w


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bfs_matches_oracle(seed):
    g, u, v, w = _setup(seed=seed)
    root = int(select_root(u, v, g.n))
    assert root == H.select_root_np(g.u, g.v, g.n)
    d, p = bfs(u, v, g.n, jnp.int32(root))
    dn, pn = H.bfs_np(g.u, g.v, g.n, root)
    assert np.array_equal(np.asarray(d), dn)
    assert np.array_equal(np.asarray(p), pn)


@pytest.mark.parametrize(
    "seed,weight",
    cases(integers(0, 10_000), sampled_from(["lognormal", "ties"]),
          n_cases=20, seed=2024),
)
def test_boruvka_equals_kruskal(seed, weight):
    g, u, v, w = _setup(n=40, m=90, seed=seed, weight=weight)
    root = int(select_root(u, v, g.n))
    d, _ = bfs(u, v, g.n, jnp.int32(root))
    eff = effective_weights(u, v, w, d, g.n)
    perm = sort_f32_desc_stable(eff)
    rank = np.empty(g.m, np.int32)
    rank[np.asarray(perm)] = np.arange(g.m)
    tree_dev = np.asarray(boruvka_mst(u, v, jnp.asarray(rank), g.n))
    tree_ref = kruskal_mst_numpy(g.u, g.v, rank, g.n)
    assert np.array_equal(tree_dev, tree_ref)
    assert tree_dev.sum() == g.n - 1


def test_lca_brute_force():
    g, u, v, w = _setup(n=50, m=100, seed=3)
    root = int(select_root(u, v, g.n))
    d, p = bfs(u, v, g.n, jnp.int32(root))
    t = build_lifting(p, d, g.n)
    dn, pn = np.asarray(d), np.asarray(p)

    def brute(a, b):
        pa, pb = a, b
        seen = set()
        while pa != -1:
            seen.add(pa)
            pa = pn[pa] if pn[pa] >= 0 else -1
        seen.add(root)
        while pb not in seen:
            pb = pn[pb]
        return pb

    rng = np.random.default_rng(0)
    a = rng.integers(0, g.n, 80).astype(np.int32)
    b = rng.integers(0, g.n, 80).astype(np.int32)
    got = np.asarray(lca(t, jnp.asarray(a), jnp.asarray(b)))
    want = np.array([brute(int(x), int(y)) for x, y in zip(a, b)])
    assert np.array_equal(got, want)
    # shortcut variant agrees
    got2 = np.asarray(lca_with_shortcut(t, jnp.int32(root), jnp.asarray(a),
                                        jnp.asarray(b)))
    assert np.array_equal(got2, want)
    # numpy mirror agrees
    up_np = H.build_lifting_np(pn, dn, g.n)
    got3 = H.lca_np(up_np, dn, a, b)
    assert np.array_equal(got3, want)


def test_resistance_vs_dense_laplacian():
    """R_tree from root-path sums == pseudo-inverse of the tree Laplacian."""
    g, u, v, w = _setup(n=30, m=60, seed=4)
    root = int(select_root(u, v, g.n))
    d0, _ = bfs(u, v, g.n, jnp.int32(root))
    eff = effective_weights(u, v, w, d0, g.n)
    perm = sort_f32_desc_stable(eff)
    rank = np.empty(g.m, np.int32)
    rank[np.asarray(perm)] = np.arange(g.m)
    tmask = boruvka_mst(u, v, jnp.asarray(rank), g.n)
    dt, pt = bfs(u, v, g.n, jnp.int32(root), edge_mask=tmask)
    t = build_lifting(pt, dt, g.n)
    inv_w = node_parent_inv_w(u, v, w, tmask, pt, g.n)
    r = root_path_sums(t, inv_w)
    el = lca(t, u, v)
    rdev = np.asarray(edge_resistance(t, r, u, v, el))

    # dense ground truth
    lap = np.zeros((g.n, g.n))
    tm = np.asarray(tmask)
    for i in range(g.m):
        if tm[i]:
            a, b, wt = int(g.u[i]), int(g.v[i]), float(g.w[i])
            lap[a, a] += wt
            lap[b, b] += wt
            lap[a, b] -= wt
            lap[b, a] -= wt
    pinv = np.linalg.pinv(lap)
    for i in range(g.m):
        a, b = int(g.u[i]), int(g.v[i])
        want = pinv[a, a] + pinv[b, b] - 2 * pinv[a, b]
        assert abs(rdev[i] - want) < 1e-3 * max(1.0, abs(want))


def test_subroot_depth1():
    g, u, v, w = _setup(n=40, m=80, seed=5)
    root = int(select_root(u, v, g.n))
    d, p = bfs(u, v, g.n, jnp.int32(root))
    t = build_lifting(p, d, g.n)
    nodes = jnp.arange(g.n, dtype=jnp.int32)
    sr = np.asarray(subroot(t, nodes))
    dn, pn = np.asarray(d), np.asarray(p)
    for x in range(g.n):
        if x == root:
            assert sr[x] == root
        else:
            y = x
            while dn[y] > 1:
                y = pn[y]
            assert sr[x] == y
