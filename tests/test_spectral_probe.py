"""Calibration of the solver-free ER estimator against the dense pinv.

`core/spectral_probe.py` estimates effective resistances with nothing
but spmv; this file is where it earns the right to stand in for the
O(n³) dense oracle at sizes the oracle cannot reach
(tests/test_spectral_quality_scale.py). The contract, asserted per
graph family at n ≤ 2048: Spearman rank correlation ≥ 0.95 between the
estimated and dense criticality orderings (w·R̂ vs w·R over off-tree
edges), via the `resistance.probe_calibration_np` seam.

Probe-budget / error tradeoff (measured on this suite's families,
Chebyshev filter, k = 64 smoothing rounds — the numbers behind the
budgets pinned below; error is Hutchinson-variance-bound once k ≳ 64,
so probes P are the knob that matters after that):

    P     median rel err    Spearman(crit)  [random / feeder / grid]
    32    ~0.16             0.92 / 0.85 / 0.68
    64    ~0.11             0.96 / 0.91 / 0.80
    128   ~0.08             0.98 / 0.95 / 0.88
    256   ~0.055            0.99 / 0.97 / 0.93
    512+  ~0.04             0.99 / 0.985 / 0.96+

The relative noise per edge tracks the Hutchinson sqrt(2/P); families
whose criticalities cluster tightly (2-D grids: many symmetric chords
with near-equal w·R) need more probes for the same rank fidelity, which
is why the grid sweep below runs P = 768 where random graphs pass at
256. Truncation (finite k) only shows up below λ ≈ 8/k² and
*underestimates* — it cannot flip ranks of well-separated edges.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _prop import cases, integers, sampled_from
from repro.core.graph import (GraphBatch, feeder_like_graph,
                              powergrid_like_graph,
                              random_connected_graph, trivial_graph)
from repro.core.resistance import probe_calibration_np, spearman_np
from repro.core.spectral_probe import (auto_lam_min, laplacian_spmv,
                                       probe_criticality,
                                       probe_edge_resistance,
                                       probe_edge_resistance_batched,
                                       trace_similarity)
from repro.core.sparsify import phase1_device


def _offtree(g, **phase1_kw):
    d = jax.device_get(phase1_device(
        jnp.asarray(g.u, jnp.int32), jnp.asarray(g.v, jnp.int32),
        jnp.asarray(g.w, jnp.float32), g.n, **phase1_kw))
    return ~d["tree_mask"].astype(bool), d


def _calibrate(g, off, n_probes, n_iters, seed):
    r_hat = np.asarray(probe_edge_resistance(
        g.u, g.v, g.w, g.n, n_probes=n_probes, n_iters=n_iters,
        seed=seed))
    assert np.isfinite(r_hat).all()
    return probe_calibration_np(
        g.n, g.u, g.v, g.w, g.u[off], g.v[off], g.w[off], r_hat[off])


# --- the calibration contract, per family ---------------------------------

@pytest.mark.parametrize(
    "seed,weight",
    cases(integers(0, 100_000), sampled_from(["lognormal", "uniform"]),
          n_cases=3, seed=97),
)
def test_calibration_random_family(seed, weight):
    g = random_connected_graph(768, 1536, seed=seed, weight=weight)
    off, _ = _offtree(g)
    cal = _calibrate(g, off, n_probes=256, n_iters=64, seed=seed)
    # the contract bar is the *criticality* ordering — what the greedy
    # sorts by; raw ER ranks are held slightly looser (uniform weights
    # cluster resistances tightly, crit separates them)
    assert cal["spearman_crit"] >= 0.95
    assert cal["spearman_er"] >= 0.90
    assert cal["med_rel_err"] <= 0.12


@pytest.mark.parametrize("seed", cases(integers(0, 100_000),
                                       n_cases=3, seed=101))
def test_calibration_feeder_family(seed):
    g = feeder_like_graph(1024, 512, span=24, seed=seed)
    off, _ = _offtree(g)
    cal = _calibrate(g, off, n_probes=256, n_iters=64, seed=seed)
    assert cal["spearman_crit"] >= 0.95
    assert cal["med_rel_err"] <= 0.12


@pytest.mark.parametrize("seed", cases(integers(0, 100_000),
                                       n_cases=2, seed=103))
def test_calibration_grid_family(seed):
    # tightly clustered criticalities: the variance-hungry family
    g = powergrid_like_graph(24, 0.25, seed=seed)
    off, _ = _offtree(g)
    cal = _calibrate(g, off, n_probes=768, n_iters=64, seed=seed)
    assert cal["spearman_crit"] >= 0.95
    assert cal["med_rel_err"] <= 0.08


def test_both_filters_calibrate():
    """Jacobi and Chebyshev are interchangeable filters at equal budget
    (Chebyshev resolves deeper per round; at k = 64 / n = 768 both are
    already variance-bound)."""
    g = random_connected_graph(768, 1536, seed=5)
    off, _ = _offtree(g)
    for method in ("cheby", "jacobi"):
        r_hat = np.asarray(probe_edge_resistance(
            g.u, g.v, g.w, g.n, n_probes=256, n_iters=64,
            method=method, seed=5))
        cal = probe_calibration_np(
            g.n, g.u, g.v, g.w, g.u[off], g.v[off], g.w[off], r_hat[off])
        assert cal["spearman_crit"] >= 0.95, method


def test_probe_budget_buys_accuracy():
    """The documented tradeoff: quadrupling probes ~halves the relative
    error (Hutchinson sqrt(2/P)); smoothing rounds beyond ~64 buy
    nothing once variance dominates."""
    g = random_connected_graph(512, 1024, seed=7)
    off, _ = _offtree(g)
    errs = {p: _calibrate(g, off, n_probes=p, n_iters=64, seed=7)[
        "med_rel_err"] for p in (16, 64, 256)}
    assert errs[64] < errs[16]
    assert errs[256] < 0.6 * errs[64]
    more_iters = _calibrate(g, off, n_probes=64, n_iters=160, seed=7)
    assert abs(more_iters["med_rel_err"] - errs[64]) < 0.03


def test_trace_similarity_is_trace_identity():
    """Σ_e w_e R_G(e) = tr(L⁺L) = n − 1 on a connected graph: the
    full-graph trace score must land on that identity (variance ±, the
    truncation bias strictly −), and must be monotone in the mask."""
    g = random_connected_graph(400, 900, seed=9)
    r_hat = probe_edge_resistance(g.u, g.v, g.w, g.n, n_probes=256,
                                  n_iters=64, seed=9)
    full = float(trace_similarity(jnp.asarray(g.w), r_hat))
    assert 0.85 * (g.n - 1) <= full <= 1.10 * (g.n - 1)
    rng = np.random.default_rng(0)
    small = rng.random(g.m) < 0.4
    big = small | (rng.random(g.m) < 0.4)
    t_small = float(trace_similarity(jnp.asarray(g.w), r_hat,
                                     jnp.asarray(small)))
    t_big = float(trace_similarity(jnp.asarray(g.w), r_hat,
                                   jnp.asarray(big)))
    assert 0.0 <= t_small <= t_big <= full + 1e-3


def test_batched_matches_padded_single_runs():
    """One vmapped dispatch over a padded batch is bit-identical to
    per-graph runs on the same padded arrays (seed + lane index). The
    padding itself only reshapes the Rademacher draw — real-slot
    results of a padded lane are a different same-distribution sketch
    than an unpadded run, so the equality contract is stated (and
    asserted) on identical padded shapes."""
    gs = [random_connected_graph(48 + 16 * i, 90 + 30 * i, seed=20 + i)
          for i in range(3)]
    b = GraphBatch.from_graphs(gs, n_max=128, L_max=256)
    rb = np.asarray(probe_edge_resistance_batched(
        b.u, b.v, b.w, b.edge_valid, b.n_max, n_probes=32, n_iters=32,
        seed=40))
    for i, g in enumerate(gs):
        ri = np.asarray(probe_edge_resistance(
            b.u[i], b.v[i], b.w[i], b.n_max,
            n_probes=32, n_iters=32, seed=40 + i,
            edge_valid=b.edge_valid[i]))
        np.testing.assert_array_equal(rb[i], ri)
        assert np.isfinite(rb[i]).all()
        # padded lanes still calibrate on the real slots
        off, _ = _offtree(g)
        cal = probe_calibration_np(
            g.n, g.u, g.v, g.w, g.u[off], g.v[off], g.w[off],
            rb[i, : g.m][off])
        assert cal["spearman_er"] > 0.5  # tiny graph, tiny budget


# --- negative / degenerate coverage ---------------------------------------

def test_edgeless_graphs_return_empty_and_zero():
    """m == 0 (the trivial placeholder and an edgeless forest): the
    estimator returns empty / finite-zero results, never NaN."""
    t = trivial_graph()
    r = np.asarray(probe_edge_resistance(t.u, t.v, t.w, t.n,
                                         n_probes=8, n_iters=8))
    assert r.shape == (0,)
    assert float(trace_similarity(jnp.asarray(t.w),
                                  jnp.asarray(r))) == 0.0
    # 5 isolated nodes, explicit node queries: zero-degree nodes carry
    # zero probes and zero solution — R̂ pins to 0.0, not NaN/inf
    qu = np.array([0, 1, 2], np.int32)
    qv = np.array([3, 4, 0], np.int32)
    r = np.asarray(probe_edge_resistance(
        np.zeros(0, np.int32), np.zeros(0, np.int32),
        np.zeros(0, np.float32), 5, qu, qv, n_probes=8, n_iters=8))
    np.testing.assert_array_equal(r, np.zeros(3, np.float32))


def test_disconnected_forest_stays_finite_and_calibrated():
    """Two components: intra-component estimates keep the calibration
    contract; cross-component queries (true R = ∞) return finite
    filter-saturated values — bounded garbage, pinned here so a
    refactor cannot silently start emitting NaN/inf through the masks.
    (The dense pinv oracle is finite across components too — the
    pseudoinverse drops the per-component null spaces — so intra-
    component calibration is the only well-posed comparison.)"""
    g1 = random_connected_graph(300, 600, seed=31)
    g2 = random_connected_graph(200, 400, seed=32)
    n = g1.n + g2.n
    u = np.concatenate([g1.u, g2.u + g1.n]).astype(np.int32)
    v = np.concatenate([g1.v, g2.v + g1.n]).astype(np.int32)
    w = np.concatenate([g1.w, g2.w]).astype(np.float32)
    r_hat = np.asarray(probe_edge_resistance(u, v, w, n, n_probes=256,
                                             n_iters=64, seed=33))
    assert np.isfinite(r_hat).all()
    assert (r_hat > 0).all()
    cal = probe_calibration_np(n, u, v, w, u, v, w, r_hat)
    assert cal["spearman_er"] >= 0.95
    # cross-component: finite, and bounded by the filter's reach
    qu = np.arange(8, dtype=np.int32)
    qv = (g1.n + np.arange(8)).astype(np.int32)
    r_x = np.asarray(probe_edge_resistance(u, v, w, n, qu, qv,
                                           n_probes=64, n_iters=64,
                                           seed=34))
    assert np.isfinite(r_x).all()


def test_uniform_weight_ties_rank_cleanly():
    """All-equal weights (the `ties` stress of the sort tier): R̂ stays
    finite and the pure-ER ordering still calibrates — tie-heavy
    criticalities must not push NaN through rank computation (the
    Spearman seam averages tied ranks)."""
    g = random_connected_graph(512, 1024, seed=41)
    g.w[:] = np.float32(1.0)
    off, _ = _offtree(g)
    # constant weights collapse the criticality spread to the bare ER
    # spread — the variance-hungriest case here (P=256 → 0.91, 512 →
    # 0.95, 768 → 0.97 measured), so this test pays for 768 probes
    cal = _calibrate(g, off, n_probes=768, n_iters=64, seed=41)
    assert cal["spearman_er"] >= 0.95
    assert cal["spearman_crit"] >= 0.95  # crit == ER when w is constant
    assert spearman_np(np.ones(5), np.ones(5)) == 1.0  # tie convention


def test_float32_extreme_weights_no_nan():
    """Weights spanning 1e-6..1e6 through BOTH the estimator and the
    pipeline's tree-resistance `criticality`: everything stays finite
    (float32 can represent w·R here; degree normalisation keeps the
    filter's spectrum in [0, 2] regardless of weight scale), and the
    estimated criticality ordering still tracks the dense one."""
    rng = np.random.default_rng(51)
    g = random_connected_graph(512, 1024, seed=51)
    g.w = np.float32(10.0) ** rng.uniform(-6, 6, g.m).astype(np.float32)
    off, d = _offtree(g)
    # pipeline criticality (w · R_tree) with extreme weights: finite
    assert np.isfinite(d["crit"][off]).all()
    assert (d["crit"][off] > 0).all()
    r_hat = np.asarray(probe_edge_resistance(
        g.u, g.v, g.w, g.n, n_probes=256, n_iters=64, seed=51))
    assert np.isfinite(r_hat).all()
    crit_hat = np.asarray(probe_criticality(jnp.asarray(g.w),
                                            jnp.asarray(r_hat)))
    assert np.isfinite(crit_hat).all()
    cal = probe_calibration_np(
        g.n, g.u, g.v, g.w, g.u[off], g.v[off], g.w[off], r_hat[off])
    # 12 decades of weight spread separate criticalities widely: the
    # ordering is *easier* than uniform weights, not harder
    assert cal["spearman_crit"] >= 0.95


def test_auto_lam_min_matches_iteration_budget():
    assert auto_lam_min(64) == pytest.approx(8.0 / 64**2)
    assert auto_lam_min(2) == 0.5  # clamped: tiny budgets stay sane
    # spmv masked == spmv on zeroed weights (the padding contract)
    g = random_connected_graph(64, 128, seed=61)
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n, 4), jnp.float32)
    valid = np.ones(g.m, bool)
    valid[::3] = False
    y_masked = laplacian_spmv(jnp.asarray(g.u), jnp.asarray(g.v),
                              jnp.asarray(g.w), x,
                              edge_valid=jnp.asarray(valid))
    y_zeroed = laplacian_spmv(jnp.asarray(g.u), jnp.asarray(g.v),
                              jnp.asarray(np.where(valid, g.w, 0.0),
                                          np.float32), x)
    np.testing.assert_array_equal(np.asarray(y_masked),
                                  np.asarray(y_zeroed))
