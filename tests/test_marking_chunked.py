"""Chunked phase-1 marking: every schedule must be BIT-IDENTICAL.

The contract: `phase1_chunked` (any block size), the legacy scan
schedules (`phase1_basic`, `phase1_parallel`), the numpy oracle
(`_host.phase1_np`) and the batched vmapped path all produce the same
per-slot accept decisions and per-group overflow flags — across graph
families (feeder included), chunk sizes {1, 3, C > L, pow2}, the
k_cap=1 overflow regime, and the Euler-LCA / Pallas-kernel distance
backends. Plus the degenerate-layout regressions: L == 0 and
zero-crossing inputs must flow through marking AND recovery without
NaN/garbage.

Shapes are reused across sweep cases so the run costs a handful of XLA
compiles, not one per case.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _prop import cases, integers, sampled_from
from repro.core import (baseline_sparsify, lgrass_sparsify,
                        lgrass_sparsify_batch)
from repro.core import _host as H
from repro.core.graph import (Graph, feeder_like_graph,
                              powergrid_like_graph, random_connected_graph)
from repro.core.sparsify import phase1_device, phase1_device_batched

CHUNKS = (1, 3, 16, 4096)  # 1, odd, pow2, C > L


def _phase1(g, **kw):
    d = phase1_device(
        jnp.asarray(g.u, jnp.int32), jnp.asarray(g.v, jnp.int32),
        jnp.asarray(g.w, jnp.float32), g.n, **kw)
    return {k: np.asarray(val) for k, val in d.items()}


def _oracle(d, k_cap=32):
    perm = d["perm"].astype(np.int64)
    active = d["crossing"].astype(bool)[perm]
    return H.phase1_np(
        d["up"], d["depth_t"], d["u_sorted"], d["v_sorted"],
        d["beta"][perm], d["gidx"], active, k_cap)


def _assert_schedules_agree(g, k_cap=32):
    """scan(basic) == scan(lockstep) == chunked(all sizes) == oracle."""
    ref = _phase1(g, k_cap=k_cap, schedule="scan", parallel=False)
    par = _phase1(g, k_cap=k_cap, schedule="scan", parallel=True)
    assert np.array_equal(ref["accept_sorted"], par["accept_sorted"])
    assert np.array_equal(ref["group_overflow"], par["group_overflow"])
    for c in CHUNKS:
        chk = _phase1(g, k_cap=k_cap, schedule="chunked", p1_chunk=c)
        assert np.array_equal(ref["accept_sorted"], chk["accept_sorted"]), c
        assert np.array_equal(ref["group_overflow"],
                              chk["group_overflow"]), c
    perm = ref["perm"].astype(np.int64)
    ref["u_sorted"] = g.u.astype(np.int64)[perm]
    ref["v_sorted"] = g.v.astype(np.int64)[perm]
    want_acc, want_ovf = _oracle(ref, k_cap=k_cap)
    assert np.array_equal(ref["accept_sorted"], want_acc)
    # device overflow is per dense group; oracle marks the same groups
    assert np.array_equal(ref["group_overflow"].astype(bool), want_ovf)
    return ref


@pytest.mark.parametrize(
    "seed,weight",
    cases(integers(0, 100_000), sampled_from(["lognormal", "ties"]),
          n_cases=8, seed=47),
)
def test_chunked_parity_random_sweep(seed, weight):
    g = random_connected_graph(36, 80, seed=seed, weight=weight)
    _assert_schedules_agree(g)


def test_chunked_parity_powergrid():
    _assert_schedules_agree(powergrid_like_graph(6, 0.4, seed=2))


@pytest.mark.parametrize("seed", [0, 3])
def test_chunked_parity_feeder(seed):
    """Chain-heavy feeder graphs: almost everything is non-crossing, so
    the active prefix is short — the chunked while_loop must stop at
    ceil(n_crossing / C) blocks yet still agree bit-for-bit."""
    _assert_schedules_agree(feeder_like_graph(96, 48, span=6, seed=seed))


def test_chunked_parity_overflow_k_cap_1():
    """k_cap=1 overflows nearly every group: the chunked engine's
    mid-block count arithmetic must raise exactly the same overflow
    flags as the per-slot scan."""
    g = random_connected_graph(40, 110, seed=9)
    ref = _assert_schedules_agree(g, k_cap=1)
    assert ref["group_overflow"].astype(bool).any()


@pytest.mark.parametrize("p1_chunk", [1, 16])
def test_chunked_e2e_matches_baseline(p1_chunk):
    """Through the fused device program (marking + recovery) the chunked
    schedule must still land exactly on the baseline greedy."""
    g = random_connected_graph(45, 90, seed=1, weight="ties")
    base = baseline_sparsify(g, budget=8)
    dev = lgrass_sparsify(g, budget=8, schedule="chunked",
                          p1_chunk=p1_chunk)
    assert np.array_equal(dev.edge_mask, base.edge_mask)
    host = lgrass_sparsify(g, budget=8, schedule="chunked",
                           p1_chunk=p1_chunk, recovery="host")
    assert np.array_equal(dev.edge_mask, host.edge_mask)


def test_chunked_batched_matches_scan_batched():
    """The vmapped batched path: chunked == scan == baseline per graph."""
    graphs = [
        random_connected_graph(30, 60, seed=0, weight="lognormal"),
        powergrid_like_graph(6, 0.4, seed=3),
        feeder_like_graph(64, 32, span=5, seed=1),
        random_connected_graph(45, 110, seed=1, weight="ties"),
    ]
    chk = lgrass_sparsify_batch(graphs, budget=6, schedule="chunked")
    scn = lgrass_sparsify_batch(graphs, budget=6, schedule="scan")
    for g, a, b in zip(graphs, chk, scn):
        assert np.array_equal(a.edge_mask, b.edge_mask)
        assert np.array_equal(
            a.edge_mask, baseline_sparsify(g, budget=6).edge_mask)
        assert (a.n_accepted, a.n_groups, a.n_overflow_groups, a.n_dirty) \
            == (b.n_accepted, b.n_groups, b.n_overflow_groups, b.n_dirty)


def test_chunked_batched_phase1_views_match_single():
    """Raw batched phase-1 outputs agree with per-graph runs slot by
    slot (padding invisible), for the chunked schedule."""
    graphs = [
        random_connected_graph(30, 60, seed=5),
        random_connected_graph(24, 40, seed=6),
    ]
    from repro.core.graph import GraphBatch

    batch = GraphBatch.from_graphs(graphs)
    d = phase1_device_batched(
        jnp.asarray(batch.u, jnp.int32), jnp.asarray(batch.v, jnp.int32),
        jnp.asarray(batch.w, jnp.float32),
        jnp.asarray(batch.edge_valid, bool), batch.n_max,
        schedule="chunked", p1_chunk=8)
    d = {k: np.asarray(val) for k, val in d.items()}
    for i, g in enumerate(graphs):
        single = _phase1(g, schedule="chunked", p1_chunk=8)
        # sorted-slot outputs: real slots lead (padding sorts last)
        assert np.array_equal(d["accept_sorted"][i][: g.m],
                              single["accept_sorted"])
        assert np.array_equal(d["perm"][i][: g.m], single["perm"])


def test_chunked_euler_lca_backend_parity():
    """The Euler-tour O(1)-LCA distance backend (the default) must be
    bit-identical to the binary-lifting climbs inside the chunked cover
    tables — use_euler_lca=False pins the lifting side explicitly since
    the default is True."""
    for seed in (0, 4):
        g = random_connected_graph(36, 80, seed=seed)
        lift = lgrass_sparsify(g, budget=7, schedule="chunked",
                               use_euler_lca=False)
        eul = lgrass_sparsify(g, budget=7, schedule="chunked",
                              use_euler_lca=True)
        assert np.array_equal(lift.edge_mask, eul.edge_mask)
    g = feeder_like_graph(96, 48, span=6, seed=1)
    assert np.array_equal(
        lgrass_sparsify(g, budget=6, schedule="chunked",
                        use_euler_lca=False).edge_mask,
        lgrass_sparsify(g, budget=6, schedule="chunked",
                        use_euler_lca=True).edge_mask)


def test_chunked_tree_kernel_backend_parity():
    """Pallas tree-distance kernel (interpret mode on CPU) backing the
    chunked cover tables: bit-identical through the fused program."""
    g = random_connected_graph(24, 40, seed=5)
    ref = lgrass_sparsify(g, budget=5, schedule="chunked")
    ker = lgrass_sparsify(g, budget=5, schedule="chunked",
                          use_tree_kernel=True)
    assert np.array_equal(ref.edge_mask, ker.edge_mask)


def test_unknown_schedule_raises():
    g = random_connected_graph(20, 30, seed=0)
    with pytest.raises(ValueError):
        lgrass_sparsify(g, budget=3, schedule="lockstep")


# --- degenerate GroupLayout regressions (L == 0 / zero crossing) --------


def _star_graph(n=8):
    return Graph(n=n, u=np.zeros(n - 1, np.int32),
                 v=np.arange(1, n, dtype=np.int32),
                 w=np.ones(n - 1, np.float32))


def _chain_noncrossing():
    """Chain + chords whose LCA is an endpoint: zero crossing edges."""
    u = np.array([0, 1, 2, 3, 4, 0, 2], np.int32)
    v = np.array([1, 2, 3, 4, 5, 2, 4], np.int32)
    return Graph(n=6, u=u, v=v, w=np.ones(7, np.float32))


@pytest.mark.parametrize("schedule", ["chunked", "scan"])
def test_degenerate_star_all_tree(schedule):
    """Every edge is a tree edge: no crossing groups, nothing accepted,
    and the final mask is exactly the tree."""
    g = _star_graph()
    base = baseline_sparsify(g, budget=2)
    r = lgrass_sparsify(g, budget=2, schedule=schedule)
    assert np.array_equal(r.edge_mask, base.edge_mask)
    assert r.edge_mask.all() and r.n_accepted == 0
    assert r.n_overflow_groups == 0 and r.n_dirty == 0


@pytest.mark.parametrize("schedule", ["chunked", "scan"])
def test_degenerate_all_noncrossing(schedule):
    """Zero crossing edges: the whole layout is the inactive tail group;
    recovery alone must decide the chords, matching the baseline."""
    g = _chain_noncrossing()
    base = baseline_sparsify(g, budget=2)
    for recovery in ("device", "host"):
        r = lgrass_sparsify(g, budget=2, schedule=schedule,
                            recovery=recovery)
        assert np.array_equal(r.edge_mask, base.edge_mask)


@pytest.mark.parametrize("schedule", ["chunked", "scan"])
def test_degenerate_zero_edges(schedule):
    """L == 0 (isolated node): the empty-layout branch must flow through
    marking AND recovery — this used to raise IndexError in
    build_group_layout (`.at[0]` on an empty array)."""
    g = Graph(n=1, u=np.zeros(0, np.int32), v=np.zeros(0, np.int32),
              w=np.zeros(0, np.float32))
    for recovery in ("device", "host"):
        r = lgrass_sparsify(g, budget=1, schedule=schedule,
                            recovery=recovery)
        assert r.edge_mask.shape == (0,)
        assert r.n_accepted == 0 and r.n_groups == 0


def test_degenerate_no_garbage_reaches_recovery():
    """The phase-1 views handed to recovery must be finite and in-range
    for zero-crossing inputs: no NaN criticality keys on off-tree slots,
    every group index in [-1, L), no spurious dirty seeds."""
    from repro.core.sparsify import phase1_views_np

    for g in (_star_graph(), _chain_noncrossing()):
        d = _phase1(g, schedule="chunked")
        tree, crossing, accept, group, dirty0, order = phase1_views_np(
            d, g.m)
        offtree = ~tree
        assert np.isfinite(d["crit"][: g.m][offtree]).all()
        assert not crossing.any()
        assert not accept.any() and not dirty0.any()
        assert (group == -1).all()
        assert sorted(order.tolist()) == list(range(g.m))
