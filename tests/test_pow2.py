"""Shared pow2 helpers (core/pow2.py) — the single home consolidating the
former per-module copies (core/sparsify.py, serve/sparsify_service.py,
core/lca.py, core/_host.py)."""
import pytest

from repro.core.pow2 import log2_ceil, next_pow2


def test_next_pow2_known_values():
    assert [next_pow2(x) for x in (1, 2, 3, 4, 5, 63, 64, 65, 1023)] == [
        1, 2, 4, 4, 8, 64, 64, 128, 1024]


def test_log2_ceil_known_values():
    assert [log2_ceil(n) for n in (1, 2, 3, 4, 5, 64, 65)] == [
        1, 1, 2, 2, 3, 6, 7]


@pytest.mark.parametrize("n", list(range(1, 200)) + [2**20 - 1, 2**20 + 1])
def test_pow2_invariants(n):
    p = next_pow2(n)
    assert p >= n and p & (p - 1) == 0          # pow2 upper bound
    assert n == 1 or p // 2 < n                 # tight
    k = log2_ceil(n)
    assert (1 << k) >= n and k >= 1
    if n >= 2:
        assert (1 << k) == p                    # the two helpers agree


def test_consumers_share_one_implementation():
    from repro.core import lca, sparsify
    from repro.serve import sparsify_service

    assert sparsify_service.next_pow2 is next_pow2
    assert sparsify.next_pow2 is next_pow2
    assert lca._log2_ceil is log2_ceil
