"""Per-arch smoke tests: reduced configs of every assigned architecture
run one forward + one train step on CPU; output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.model import LM
from repro.optim.optimizer import OptConfig
from repro.train.train_step import make_train_state, make_train_step

ARCH_NAMES = sorted(ARCHS)


def _batch_for(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    if cfg.is_encoder:
        return dict(
            features=jnp.asarray(
                rng.standard_normal((b, s, cfg.feat_dim)), jnp.float32),
            labels=jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                               jnp.int32),
            mask=jnp.asarray(rng.random((b, s)) < 0.5),
        )
    toks = rng.integers(0, cfg.vocab_size, (b, s + 1))
    return dict(tokens=jnp.asarray(toks[:, :-1], jnp.int32),
                labels=jnp.asarray(toks[:, 1:], jnp.int32))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_forward_and_train_step(name):
    cfg = ARCHS[name].reduced()
    model = LM(cfg)
    state = make_train_state(model, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss0, metrics = model.loss_fn(state["params"], batch)
    assert np.isfinite(float(loss0)), name
    step = make_train_step(model, OptConfig(peak_lr=1e-3, warmup_steps=1,
                                            total_steps=10))
    state2, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    assert int(state2["opt"]["step"]) == 1
    # params actually changed
    l0 = jax.tree.leaves(state["params"])[0]
    l1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_exact_assigned_config(name):
    """Full (unreduced) configs build abstract param trees with the exact
    assigned dimensions — no allocation via eval_shape."""
    cfg = ARCHS[name]
    model = LM(cfg)
    shapes = jax.eval_shape(
        lambda r: model.init(r)[0], jax.random.PRNGKey(0))
    emb = shapes["embedding"]
    assert emb.shape == (cfg.vocab_size, cfg.d_model)
    n_leaf_params = sum(int(np.prod(l.shape))
                        for l in jax.tree.leaves(shapes))
    assert abs(n_leaf_params - cfg.n_params()) / cfg.n_params() < 0.01


def test_microbatched_step_matches_full():
    cfg = ARCHS["phi3-mini-3.8b"].reduced()
    model = LM(cfg)
    state = make_train_state(model, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, b=4)
    s_full, m_full = make_train_step(model, OptConfig())(state, batch)
    state_b = make_train_state(model, jax.random.PRNGKey(0))
    s_micro, m_micro = make_train_step(model, OptConfig(),
                                       micro_batches=2)(state_b, batch)
    np.testing.assert_allclose(float(m_full["loss"]),
                               float(m_micro["loss"]), rtol=1e-5)
    a = jax.tree.leaves(s_full["params"])[1]
    b = jax.tree.leaves(s_micro["params"])[1]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
