"""THE reproduction test: LGRASS (linear, parallel) must output the exact
sparsifier of the baseline program's semantics (Algorithm 1/3 greedy) —
the competition's own correctness criterion ("outputs the same result as
provided program")."""
import numpy as np
import pytest

from _prop import cases, integers, sampled_from
from repro.core import (baseline_sparsify, lgrass_sparsify,
                        powergrid_like_graph, random_connected_graph)


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("weight", ["lognormal", "ties"])
def test_lgrass_equals_baseline(seed, weight):
    g = random_connected_graph(45, 90, seed=seed, weight=weight)
    b = baseline_sparsify(g, budget=8)
    for parallel in (True, False):
        r = lgrass_sparsify(g, budget=8, parallel=parallel)
        assert np.array_equal(b.edge_mask, r.edge_mask), (
            f"seed={seed} weight={weight} parallel={parallel}")


def test_lgrass_overflow_recovery_exact():
    """k_cap=1 forces overflow in nearly every group; the recovery stage
    must still reproduce the oracle bit-exactly."""
    g = random_connected_graph(40, 110, seed=9)
    b = baseline_sparsify(g, budget=20)
    r = lgrass_sparsify(g, budget=20, k_cap=1)
    assert np.array_equal(b.edge_mask, r.edge_mask)
    assert r.n_overflow_groups >= 0


def test_lgrass_powergrid_case():
    g = powergrid_like_graph(9, 0.4, seed=2)
    b = baseline_sparsify(g, budget=10)
    r = lgrass_sparsify(g, budget=10)
    assert np.array_equal(b.edge_mask, r.edge_mask)


@pytest.mark.parametrize(
    "seed,budget,weight",
    cases(integers(0, 100_000), integers(2, 30),
          sampled_from(["lognormal", "ties"]), n_cases=20, seed=77),
)
def test_lgrass_equals_baseline_property(seed, budget, weight):
    g = random_connected_graph(36, 80, seed=seed, weight=weight)
    b = baseline_sparsify(g, budget=budget)
    r = lgrass_sparsify(g, budget=budget)
    assert np.array_equal(b.edge_mask, r.edge_mask)


def test_sparsifier_invariants():
    g = random_connected_graph(60, 150, seed=11)
    r = lgrass_sparsify(g, budget=12)
    # contains the spanning tree
    assert np.all(r.edge_mask[r.tree_mask])
    assert r.tree_mask.sum() == g.n - 1
    # accepted edges are off-tree and within budget
    assert not np.any(r.accepted_mask & r.tree_mask)
    assert r.n_accepted <= 12
    # sparsifier connects the graph (tree does already)
    # edge count = n-1 + accepted
    assert r.edge_mask.sum() == g.n - 1 + r.n_accepted


def test_budget_monotone():
    g = random_connected_graph(50, 120, seed=13)
    prev = None
    for budget in (1, 4, 8, 16):
        r = lgrass_sparsify(g, budget=budget)
        assert r.n_accepted <= budget
        if prev is not None:
            # greedy prefix property: smaller budget = prefix of larger
            assert np.all(r.accepted_mask[prev.accepted_mask] |
                          (prev.n_accepted <= r.n_accepted))
        prev = r
