"""Checkpoint/restore, fault-tolerant trainer (restart + straggler), data
pipeline determinism."""
import logging
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import Checkpointer
from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.ft.elastic import (FailureInjector, FaultConfig,
                              StragglerMonitor, resolve_spec_for_mesh)
from repro.models.model import LM
from repro.optim.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    state = {"a": jnp.arange(6).reshape(2, 3),
             "nested": {"b": jnp.ones((4,)) * 2.5},
             "lst": [jnp.zeros((2,)), jnp.ones((2,))]}
    ck.save(3, state)
    assert ck.latest_step() == 3
    got = ck.restore(3, jax.device_get(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_async(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.full((3,), s)})
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_data_determinism():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    b1 = p1.batch(5)
    b2 = p2.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p1.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted
    # (tokens[t+1] == labels[t] by construction)
    raw1 = p1._host_batch(5)
    np.testing.assert_array_equal(raw1["tokens"][:, 1:],
                                  raw1["labels"][:, :-1])


def _mk_trainer(tmp_path, fail_steps=(), total=12, ckpt_every=4,
                seq_len=16, batch=4, lr=5e-3):
    cfg = ARCHS["phi3-mini-3.8b"].reduced()
    model = LM(cfg)
    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=seq_len,
                                    global_batch=batch, seed=1))
    return Trainer(
        model, data,
        OptConfig(peak_lr=lr, warmup_steps=3, total_steps=total),
        TrainerConfig(total_steps=total, log_every=100),
        str(tmp_path),
        fault_cfg=FaultConfig(ckpt_every=ckpt_every, max_restarts=3),
        failure_injector=FailureInjector(fail_steps),
    )


def test_trainer_loss_decreases(tmp_path):
    t = _mk_trainer(tmp_path, total=40, ckpt_every=50, seq_len=32,
                    batch=8, lr=1e-2)
    out = t.run()
    losses = [h["loss"] for h in out["history"]]
    assert len(losses) == 40
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
    assert out["restarts"] == 0


def test_trainer_restarts_from_checkpoint(tmp_path):
    t = _mk_trainer(tmp_path, fail_steps=(9,), total=12, ckpt_every=4)
    out = t.run()
    assert out["restarts"] == 1
    steps = [h["step"] for h in out["history"]]
    # after failing at 9 it restarted from ckpt step 8 and replayed 8..11
    assert steps.count(8) >= 1
    assert steps[-1] == 11
    # deterministic data => replayed steps compute identical losses
    by_step = {}
    for h in out["history"]:
        by_step.setdefault(h["step"], []).append(h["loss"])
    for s, ls in by_step.items():
        if len(ls) > 1:
            assert abs(ls[0] - ls[1]) < 1e-4


def test_straggler_monitor():
    mon = StragglerMonitor(FaultConfig(straggler_factor=3.0))
    flags = [mon.observe(i, 0.1) for i in range(10)]
    assert not any(flags)
    assert mon.observe(10, 1.0)  # 10x the EWMA -> straggler
    assert len(mon.events) == 1


def test_resolve_spec_for_mesh():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    p = resolve_spec_for_mesh(P(("pod", "data"), None, "model"), mesh)
    assert p == P(("data",), None, None)
